//! Seeded workload generation for open-loop serving: per-tenant arrival
//! processes (Poisson, bursty on/off, closed-loop) and a deterministic
//! queueing simulation of the batcher + pipeline.
//!
//! The paper measures a closed 50-input batch; the ROADMAP north star is
//! heavy *open* traffic, where queueing — not raw segment latency —
//! dominates (cf. arXiv 2602.17808).  This module supplies both halves of
//! that story:
//!
//! * [`arrival_times`] draws a seeded, deterministic arrival schedule for
//!   the open processes — the same `(process, n, seed)` always yields the
//!   same schedule, on every platform (the PRNG is the in-repo
//!   xoshiro256++);
//! * [`simulate_open_loop`] pushes that schedule through a deterministic
//!   model of the dynamic batcher ([`BatchPolicy`] size/wait flush) and
//!   the pipelined stages (the same recurrence as `pipeline::simulate`:
//!   stage-busy, GIL-serialized host overhead, hop latency), yielding
//!   per-request latencies, batch boundaries and flush reasons that are
//!   **bit-for-bit reproducible** — this is what `repro loadgen` prints,
//!   while the live `ServingPool` run (real threads, real queues)
//!   verifies numerics against the same seeds;
//! * [`simulate_deployment`] generalizes that to a whole
//!   [`DeploymentSim`]: data-parallel replica fan-out (round-robin
//!   sharded, like the live `ReplicaRouter`) and time-shared
//!   [`DeviceGrant`](crate::scheduler::DeviceGrant)s, whose per-flush
//!   parameter re-loads are reported as deterministic swap totals.
//!
//! Closed-loop arrivals are endogenous (each virtual client submits its
//! next request one think-time after its previous response), so they are
//! generated inside the simulation rather than by [`arrival_times`].

use std::collections::VecDeque;

use anyhow::{Context, Result};

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::StageSim;
use crate::metrics::FlushKind;
use crate::obs::{SimTrace, SpanKind};
use crate::scheduler::paramcache::CacheEffect;
use crate::util::rng::Rng;

pub mod faults;

pub use faults::{
    simulate_chaos, ChaosConfig, ChaosRun, FaultEvent, FaultKind, FaultPlan, FaultSpec,
};

/// A per-tenant arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Memoryless open arrivals at `rate_hz` requests/second.
    Poisson {
        /// Mean offered rate (requests per simulated second).
        rate_hz: f64,
    },
    /// On/off open arrivals: Poisson at `rate_hz` during `on_s`-second
    /// bursts separated by `off_s`-second silences.
    Bursty {
        /// Mean offered rate *during a burst*.
        rate_hz: f64,
        /// Burst (on-window) length in seconds.
        on_s: f64,
        /// Silence (off-window) length in seconds.
        off_s: f64,
    },
    /// Closed loop: `concurrency` virtual clients, each submitting its
    /// next request `think_s` seconds after its previous response.
    Closed {
        /// Number of always-outstanding virtual clients.
        concurrency: usize,
        /// Per-client think time between response and next request.
        think_s: f64,
    },
}

impl Arrivals {
    /// Parse a CLI spec: `poisson:RATE`, `bursty:RATE:ON_S:OFF_S` or
    /// `closed:CONCURRENCY:THINK_S`.
    pub fn parse(spec: &str) -> Result<Arrivals> {
        let parts: Vec<&str> = spec.split(':').collect();
        let f = |s: &str| -> Result<f64> {
            s.parse::<f64>().with_context(|| format!("bad number {s:?} in arrival spec {spec:?}"))
        };
        match parts.as_slice() {
            ["poisson", rate] => {
                let rate_hz = f(rate)?;
                anyhow::ensure!(rate_hz > 0.0, "poisson rate must be positive in {spec:?}");
                Ok(Arrivals::Poisson { rate_hz })
            }
            ["bursty", rate, on, off] => {
                let (rate_hz, on_s, off_s) = (f(rate)?, f(on)?, f(off)?);
                anyhow::ensure!(
                    rate_hz > 0.0 && on_s > 0.0 && off_s >= 0.0,
                    "bursty needs rate>0, on>0, off>=0 in {spec:?}"
                );
                Ok(Arrivals::Bursty { rate_hz, on_s, off_s })
            }
            ["closed", conc, think] => {
                let concurrency: usize = conc
                    .parse()
                    .with_context(|| format!("bad concurrency {conc:?} in {spec:?}"))?;
                let think_s = f(think)?;
                anyhow::ensure!(
                    concurrency >= 1 && think_s >= 0.0,
                    "closed needs concurrency>=1, think>=0 in {spec:?}"
                );
                Ok(Arrivals::Closed { concurrency, think_s })
            }
            _ => anyhow::bail!(
                "unknown arrival spec {spec:?} \
                 (poisson:RATE | bursty:RATE:ON_S:OFF_S | closed:CONCURRENCY:THINK_S)"
            ),
        }
    }

    /// Compact stable label for tables, e.g. `poisson:400`.
    pub fn label(&self) -> String {
        match *self {
            Arrivals::Poisson { rate_hz } => format!("poisson:{rate_hz}"),
            Arrivals::Bursty { rate_hz, on_s, off_s } => {
                format!("bursty:{rate_hz}:{on_s}:{off_s}")
            }
            Arrivals::Closed { concurrency, think_s } => {
                format!("closed:{concurrency}:{think_s}")
            }
        }
    }

    /// Long-run offered rate in requests/second; `None` for closed loops
    /// (their rate is an outcome, not an input).
    pub fn offered_rate_hz(&self) -> Option<f64> {
        match *self {
            Arrivals::Poisson { rate_hz } => Some(rate_hz),
            Arrivals::Bursty { rate_hz, on_s, off_s } => {
                Some(rate_hz * on_s / (on_s + off_s))
            }
            Arrivals::Closed { .. } => None,
        }
    }
}

/// Salt separating the arrival-schedule PRNG stream from the
/// request-payload stream (both derive from the same user-facing seed).
pub const ARRIVAL_STREAM_SALT: u64 = 0xA5A5_5A5A_0F0F_F0F0;

/// The arrival-schedule seed for one tenant under a run seed: the same
/// `(run_seed, model)` pair that the live driver paces with is what the
/// deterministic simulation replays.
pub fn arrival_seed(run_seed: u64, model: &str) -> u64 {
    run_seed ^ crate::scheduler::tenant_salt(model) ^ ARRIVAL_STREAM_SALT
}

/// Salt separating the drift-injection stream from the arrival and
/// payload streams (all three derive from the same user-facing seed).
pub const DRIFT_STREAM_SALT: u64 = 0xD21F_7D21_F7D2_1F7D;

/// Seeded true-cost drift factor for one tenant: the hidden
/// observed/profiled service-time ratio a `repro loadgen --calibrate` /
/// `repro calibrate` run injects, deterministic in `(run_seed, model)`
/// and uniform in `[1.8, 2.5)`.  The floor is chosen against the
/// calibrator's histogram quantization: `LatencyHistogram` buckets grow
/// by 1.25x, so a factor >= 1.8 always moves the observed p99 at least
/// two buckets (a measured ratio >= 1.5625), safely past the default
/// 0.5 drift threshold — a drifted tenant provably recalibrates, and
/// the band is tight enough that one corrective re-plan converges.
pub fn drift_factor(run_seed: u64, model: &str) -> f64 {
    // splitmix64-style finalizer over the salted seed: any bit of the
    // seed or name flips the factor, and the result is platform-stable
    let mut z = run_seed ^ crate::scheduler::tenant_salt(model) ^ DRIFT_STREAM_SALT;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    let frac = (z >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
    1.8 + 0.7 * frac
}

/// One tenant's offered load in a `repro loadgen` run.
#[derive(Debug, Clone)]
pub struct TenantLoad {
    /// Model/routing name (must be registered in the pool).
    pub model: String,
    /// The tenant's arrival process.
    pub arrivals: Arrivals,
    /// Total requests to submit.
    pub requests: usize,
}

/// Seeded arrival schedule for an **open** process: `n` strictly ordered
/// arrival offsets in seconds from the run start.  Deterministic in
/// `(arrivals, n, seed)`.
///
/// # Panics
/// On [`Arrivals::Closed`]: closed-loop arrivals depend on completions and
/// are generated inside [`simulate_open_loop`] / the live driver.
pub fn arrival_times(arrivals: &Arrivals, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    match *arrivals {
        Arrivals::Poisson { rate_hz } => {
            let mut t = 0.0f64;
            (0..n)
                .map(|_| {
                    t += rng.exp(1.0 / rate_hz);
                    t
                })
                .collect()
        }
        Arrivals::Bursty { rate_hz, on_s, off_s } => {
            // draw in "active time", then expand every completed
            // on-window by the off-window it is followed by
            let mut tau = 0.0f64;
            (0..n)
                .map(|_| {
                    tau += rng.exp(1.0 / rate_hz);
                    let completed_windows = (tau / on_s).floor();
                    tau + completed_windows * off_s
                })
                .collect()
        }
        Arrivals::Closed { .. } => {
            panic!("closed-loop arrivals are endogenous; use simulate_open_loop")
        }
    }
}

/// One flushed batch in the deterministic open-loop simulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimBatch {
    /// Simulated instant the batch was injected into the pipeline.
    pub flush_s: f64,
    /// Requests in the batch.
    pub len: usize,
    /// Why it flushed (mirrors the live batcher's reasons; the final
    /// batch of an exhausted arrival stream reports `Closed`).
    pub kind: FlushKind,
}

/// Result of one deterministic open-loop run for a single tenant.
#[derive(Debug, Clone)]
pub struct OpenLoopRun {
    /// Per-request latency (arrival to pipeline exit), indexed by id.
    pub latencies_s: Vec<f64>,
    /// Every flushed batch, in flush order.
    pub batches: Vec<SimBatch>,
    /// Completion time of the last request.
    pub makespan_s: f64,
    /// Context switches of a time-shared deployment: one per flushed
    /// batch (the co-resident ran in between), 0 when exclusive.
    pub swaps: usize,
    /// Total simulated parameter re-load time across those swaps, summed
    /// over stages and replicas.
    pub swap_overhead_s: f64,
    /// Warm swaps under a segment-parameter cache: residency + prefetch
    /// hid the entire re-load.  0 when the deployment carries no cache.
    pub cache_hits: usize,
    /// Cold or partial swaps under the cache (the first swap is always a
    /// compulsory miss); `cache_hits + cache_misses == swaps` whenever a
    /// cache is attached.
    pub cache_misses: usize,
    /// Quantum-boundary prefetches issued (a miss with a non-zero
    /// prefetch window and unpinned bytes to fetch).
    pub prefetches: usize,
}

impl OpenLoopRun {
    /// Achieved throughput over the whole run (requests/second).
    pub fn throughput_hz(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return f64::NAN;
        }
        self.latencies_s.len() as f64 / self.makespan_s
    }

    /// Count of batches flushed for the given reason.
    pub fn flushes(&self, kind: FlushKind) -> usize {
        self.batches.iter().filter(|b| b.kind == kind).count()
    }
}

/// Deterministic model of one tenant's deployed pipelines for
/// [`simulate_deployment`]: `replicas` identical copies of the staged
/// pipeline (round-robin sharded, like the live `ReplicaRouter`),
/// optionally time-shared with co-residents.
#[derive(Debug, Clone)]
pub struct DeploymentSim {
    /// Per-stage simulated-clock parameters.  For a shared grant these
    /// are the *slice-dilated* sims (`serving::stage_sims_for_grant`).
    pub sims: Vec<StageSim>,
    /// Data-parallel pipeline copies (>= 1); a flushed batch is sharded
    /// round-robin across them, exactly like the live replica router.
    pub replicas: usize,
    /// Per-stage context-switch cost paid when a batch flush opens a new
    /// scheduling quantum (the co-resident ran in between, so the
    /// tenant's segment parameters re-load from host memory).  Empty for
    /// exclusive grants.
    pub switch_s: Vec<f64>,
    /// Scheduling-quantum length in seconds: a flush within `quantum_s`
    /// of the last paid re-load keeps the parameters resident and skips
    /// the swap.  `0` (PR 3's model) re-loads on every flush.
    pub quantum_s: f64,
    /// Planned segment-parameter cache effect for this grant
    /// ([`DeviceGrant::cache`](crate::scheduler::DeviceGrant::cache)):
    /// scales every quantum-opening re-load by its residual fraction and
    /// counts hits/misses/prefetches.  `None` (cache off) charges the
    /// full cold cost, byte-identical to the flat model.
    pub cache: Option<CacheEffect>,
}

impl DeploymentSim {
    /// An exclusive single-pipeline deployment (the pre-sharing model).
    pub fn exclusive(sims: Vec<StageSim>) -> Self {
        DeploymentSim {
            sims,
            replicas: 1,
            switch_s: Vec::new(),
            quantum_s: 0.0,
            cache: None,
        }
    }
}

/// Deterministic queueing simulation of one tenant's open-loop serving:
/// seeded arrivals -> dynamic batcher (`policy`) -> pipelined stages
/// (the same recurrence as the live simulated clock: stage-busy,
/// GIL-serialized host overhead, hop latency), single-pipeline and
/// exclusive.  See [`simulate_deployment`] for replica fan-out and
/// time-shared (co-resident) deployments.
///
/// Pure function of its arguments — calling it twice yields bit-identical
/// results, which is what makes `repro loadgen` reports reproducible.
pub fn simulate_open_loop(
    arrivals: &Arrivals,
    n: usize,
    seed: u64,
    policy: &BatchPolicy,
    sims: &[StageSim],
) -> OpenLoopRun {
    simulate_deployment(arrivals, n, seed, policy, &DeploymentSim::exclusive(sims.to_vec()))
}

/// [`simulate_open_loop`] generalized over a whole [`DeploymentSim`]:
///
/// * **replica fan-out** — each flushed batch is sharded round-robin
///   across `replicas` pipeline copies, each with its own stage clocks
///   and host (GIL) server, and the batcher stays busy until the last
///   shard's last response (the live worker serves synchronously);
/// * **time-shared grants** — every flush first re-loads the tenant's
///   segment parameters on each pipeline stage it uses (`switch_s`), and
///   the run reports the swap count and total overhead.
///
/// Pure and seed-deterministic, like [`simulate_open_loop`].
pub fn simulate_deployment(
    arrivals: &Arrivals,
    n: usize,
    seed: u64,
    policy: &BatchPolicy,
    dep: &DeploymentSim,
) -> OpenLoopRun {
    simulate_deployment_traced(arrivals, n, seed, policy, dep, None)
}

/// [`simulate_deployment`] with optional span recording: when `trace` is
/// supplied, every request lifecycle event is stamped on the **sim
/// clock** (virtual seconds, DESIGN.md §13), so the recorded spans are a
/// pure function of the arguments — two runs with the same seed serialize
/// byte-identically.
///
/// Track convention (tenant-local; callers shift by
/// [`crate::obs::span::track_base`] when merging tenants):
///
/// * track 0 — request lifecycle: `enqueue` (instant, at arrival),
///   `wait` (arrival → batch flush), `response` (arrival → done);
/// * track 1 — batcher: `flush` instants (id = batch ordinal) and `swap`
///   spans when a flush opens a new scheduling quantum;
/// * track `2 + rep * n_stages + si` — stage `si` of replica `rep`
///   executing one request (`stage`, id = request id);
/// * track [`CACHE_TRACK`](crate::obs::span::CACHE_TRACK) (the last
///   tenant-local track) — segment-parameter cache: `prefetch` spans
///   overlapping the tail of the previous quantum (only recorded for
///   deployments carrying a cache effect, so cache-off traces are
///   byte-identical).
pub fn simulate_deployment_traced(
    arrivals: &Arrivals,
    n: usize,
    seed: u64,
    policy: &BatchPolicy,
    dep: &DeploymentSim,
    mut trace: Option<&mut SimTrace>,
) -> OpenLoopRun {
    assert!(policy.max_batch >= 1);
    assert!(!dep.sims.is_empty());
    assert!(dep.replicas >= 1, "deployment needs at least one pipeline");
    assert!(
        dep.switch_s.is_empty() || dep.switch_s.len() == dep.sims.len(),
        "switch costs must align with stages"
    );
    let max_wait = policy.max_wait.as_secs_f64();

    // pending arrivals (time, id), sorted by time then id; a deque so the
    // front-to-back consumption below stays O(1) per request
    let mut pending: VecDeque<(f64, usize)> = VecDeque::new();
    let mut next_id = 0usize;
    let mut think = 0.0f64;
    let closed = matches!(arrivals, Arrivals::Closed { .. });
    if let Arrivals::Closed { concurrency, think_s } = *arrivals {
        think = think_s;
        let c = concurrency.min(n.max(1));
        for _ in 0..c {
            pending.push_back((0.0, next_id));
            next_id += 1;
        }
    } else {
        for t in arrival_times(arrivals, n, seed) {
            pending.push_back((t, next_id));
            next_id += 1;
        }
    }

    let replicas = dep.replicas;
    let mut latencies = vec![0.0f64; n];
    let mut batches: Vec<SimBatch> = Vec::new();
    // per-replica clocks: each pipeline copy has its own stages and its
    // own GIL-serialized host server (like the live `Pipeline`)
    let mut stage_free = vec![vec![0.0f64; dep.sims.len()]; replicas];
    let mut host_free = vec![0.0f64; replicas];
    let mut batcher_free = 0.0f64;
    let mut served = 0usize;
    let mut makespan = 0.0f64;
    let mut swaps = 0usize;
    let mut swap_overhead = 0.0f64;
    let mut cache_hits = 0usize;
    let mut cache_misses = 0usize;
    let mut prefetches = 0usize;
    // simulated instant of the last paid re-load: flushes inside the
    // scheduling quantum keep the parameters resident (quantum_s = 0
    // degenerates to one swap per flush)
    let mut last_swap_s = f64::NEG_INFINITY;

    while served < n {
        debug_assert!(!pending.is_empty(), "unserved requests but no pending arrivals");
        // the batcher pulls the first request once it is free and the
        // request has arrived; the wait deadline starts there
        let (t0, id0) = pending.pop_front().expect("pending checked non-empty");
        let open_t = t0.max(batcher_free);
        let deadline = open_t + max_wait;
        let mut batch = vec![(t0, id0)];
        let kind = loop {
            if batch.len() >= policy.max_batch {
                break FlushKind::Size;
            }
            match pending.front().copied() {
                Some((t, id)) if t <= deadline => {
                    pending.pop_front();
                    batch.push((t, id));
                }
                Some(_) => break FlushKind::Deadline,
                None if closed && next_id < n => {
                    // future closed-loop submissions depend on responses
                    // to THIS batch; the live batcher waits out max_wait
                    break FlushKind::Deadline;
                }
                None => break FlushKind::Closed, // arrival stream exhausted
            }
        };
        let flush_s = match kind {
            // flush fired when the size/close condition was met
            FlushKind::Size | FlushKind::Closed => {
                batch.iter().fold(open_t, |acc, &(t, _)| acc.max(t))
            }
            FlushKind::Deadline => deadline,
        };
        let batch_idx = batches.len() as u64;
        batches.push(SimBatch { flush_s, len: batch.len(), kind });
        if let Some(tr) = trace.as_deref_mut() {
            for &(t, id) in &batch {
                tr.record_s(SpanKind::Enqueue, 0, id as u64, t, t);
                tr.record_s(SpanKind::Wait, 0, id as u64, t, flush_s);
            }
            tr.record_s(SpanKind::Flush, 1, batch_idx, flush_s, flush_s);
        }

        // time-shared deployment: if this flush opens a new scheduling
        // quantum (the co-resident ran since the last one), each stage
        // this batch touches re-loads the tenant's parameters from host
        // memory before serving; flushes inside the quantum skip it
        if !dep.switch_s.is_empty() && flush_s >= last_swap_s + dep.quantum_s {
            swaps += 1;
            let first = last_swap_s == f64::NEG_INFINITY;
            last_swap_s = flush_s;
            // segment-parameter cache: the planned effect scales the cold
            // re-load down to its residual fraction (first swap = full
            // compulsory miss); no cache charges the full cold cost,
            // bit-identical to the flat model (`frac` is exactly 1.0)
            let cold_total: f64 = dep.switch_s.iter().sum();
            let frac = match dep.cache {
                Some(eff) => {
                    let class = eff.classify(cold_total, first);
                    if class.hit {
                        cache_hits += 1;
                    } else {
                        cache_misses += 1;
                    }
                    if class.prefetched {
                        prefetches += 1;
                        if let Some(tr) = trace.as_deref_mut() {
                            let start = (flush_s - eff.prefetch_s).max(0.0);
                            tr.record_s(
                                SpanKind::Prefetch,
                                crate::obs::span::CACHE_TRACK,
                                batch_idx,
                                start,
                                flush_s,
                            );
                        }
                    }
                    class.frac
                }
                None => 1.0,
            };
            let before = swap_overhead;
            for rep_clocks in stage_free.iter_mut().take(replicas.min(batch.len())) {
                for (si, &sw) in dep.switch_s.iter().enumerate() {
                    let sw = sw * frac;
                    rep_clocks[si] = rep_clocks[si].max(flush_s) + sw;
                    swap_overhead += sw;
                }
            }
            if let Some(tr) = trace.as_deref_mut() {
                let end_s = flush_s + (swap_overhead - before);
                tr.record_s(SpanKind::Swap, 1, batch_idx, flush_s, end_s);
            }
        }

        // pipeline recurrence, items in FIFO order, sharded round-robin
        // across replicas (the live ReplicaRouter's split)
        let mut last_done = flush_s;
        for (pos, &(arrival, id)) in batch.iter().enumerate() {
            let rep = pos % replicas;
            let mut t_in = flush_s;
            for (si, sim) in dep.sims.iter().enumerate() {
                let ready = t_in.max(stage_free[rep][si]);
                let dispatch = ready.max(host_free[rep]);
                host_free[rep] = dispatch + sim.overhead_s;
                let finish = dispatch + sim.overhead_s + sim.exec_s;
                stage_free[rep][si] = finish;
                t_in = finish + sim.hop_out_s;
                if let Some(tr) = trace.as_deref_mut() {
                    let track = 2 + (rep * dep.sims.len() + si) as u32;
                    tr.record_s(SpanKind::Stage, track, id as u64, dispatch, finish);
                }
            }
            let done = t_in;
            latencies[id] = done - arrival;
            if let Some(tr) = trace.as_deref_mut() {
                tr.record_s(SpanKind::Response, 0, id as u64, arrival, done);
            }
            if done > makespan {
                makespan = done;
            }
            if done > last_done {
                last_done = done;
            }
            served += 1;
            if closed && next_id < n {
                // this virtual client thinks, then submits again
                let t_next = done + think;
                let at = pending.partition_point(|&(t, _)| t <= t_next);
                pending.insert(at, (t_next, next_id));
                next_id += 1;
            }
        }
        // the live worker serves synchronously: the next batch cannot
        // open before this one's last response is back
        batcher_free = last_done;
    }

    OpenLoopRun {
        latencies_s: latencies,
        batches,
        makespan_s: makespan,
        swaps,
        swap_overhead_s: swap_overhead,
        cache_hits,
        cache_misses,
        prefetches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn sims(n: usize, exec: f64) -> Vec<StageSim> {
        (0..n)
            .map(|i| StageSim {
                exec_s: exec,
                hop_out_s: if i + 1 == n { 0.0 } else { 1e-4 },
                overhead_s: 2e-4,
            })
            .collect()
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        assert_eq!(
            Arrivals::parse("poisson:400").unwrap(),
            Arrivals::Poisson { rate_hz: 400.0 }
        );
        assert_eq!(
            Arrivals::parse("bursty:800:0.05:0.1").unwrap(),
            Arrivals::Bursty { rate_hz: 800.0, on_s: 0.05, off_s: 0.1 }
        );
        assert_eq!(
            Arrivals::parse("closed:4:0.001").unwrap(),
            Arrivals::Closed { concurrency: 4, think_s: 0.001 }
        );
        for bad in ["", "poisson", "poisson:0", "poisson:x", "uniform:3", "closed:0:1"] {
            assert!(Arrivals::parse(bad).is_err(), "{bad:?} must not parse");
        }
        // labels re-parse to the same process
        for spec in ["poisson:400", "bursty:800:0.05:0.1", "closed:4:0.001"] {
            let a = Arrivals::parse(spec).unwrap();
            assert_eq!(Arrivals::parse(&a.label()).unwrap(), a);
        }
    }

    #[test]
    fn drift_factor_is_seeded_bounded_and_tenant_dependent() {
        let a = drift_factor(7, "fc_small");
        assert_eq!(a, drift_factor(7, "fc_small"), "same (seed, model) => same factor");
        assert_ne!(a, drift_factor(8, "fc_small"), "seed must matter");
        assert_ne!(a, drift_factor(7, "conv_a"), "tenant must matter");
        for seed in 0..64u64 {
            for model in ["fc_small", "conv_a", "fc_big", "pyramid"] {
                let f = drift_factor(seed, model);
                assert!((1.8..2.5).contains(&f), "factor {f} out of band for {model}@{seed}");
            }
        }
    }

    #[test]
    fn poisson_schedule_is_seeded_ordered_and_rate_plausible() {
        let a = Arrivals::Poisson { rate_hz: 1000.0 };
        let xs = arrival_times(&a, 2000, 7);
        let ys = arrival_times(&a, 2000, 7);
        assert_eq!(xs, ys, "same seed must give the identical schedule");
        assert_ne!(xs, arrival_times(&a, 2000, 8), "seed must matter");
        for w in xs.windows(2) {
            assert!(w[1] > w[0], "arrivals must be strictly increasing");
        }
        let span = xs.last().unwrap();
        assert!((span - 2.0).abs() < 0.3, "2000 arrivals at 1kHz ~ 2s, got {span}");
    }

    #[test]
    fn bursty_arrivals_land_only_in_on_windows() {
        let (on_s, off_s) = (0.05, 0.2);
        let a = Arrivals::Bursty { rate_hz: 500.0, on_s, off_s };
        let xs = arrival_times(&a, 500, 3);
        let cycle = on_s + off_s;
        for &t in &xs {
            let phase = t % cycle;
            assert!(phase <= on_s + 1e-9, "arrival at {t} (phase {phase}) is in an off-window");
        }
        assert_eq!(a.offered_rate_hz(), Some(500.0 * 0.05 / 0.25));
    }

    #[test]
    fn open_loop_sim_is_bit_deterministic() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) };
        let s = sims(3, 1e-3);
        for a in [
            Arrivals::Poisson { rate_hz: 700.0 },
            Arrivals::Bursty { rate_hz: 900.0, on_s: 0.03, off_s: 0.05 },
            Arrivals::Closed { concurrency: 4, think_s: 1e-3 },
        ] {
            let x = simulate_open_loop(&a, 300, 7, &policy, &s);
            let y = simulate_open_loop(&a, 300, 7, &policy, &s);
            assert_eq!(x.latencies_s, y.latencies_s, "{a:?}");
            assert_eq!(x.batches, y.batches, "{a:?}: batch boundaries must be deterministic");
            assert_eq!(x.makespan_s, y.makespan_s, "{a:?}");
            // every request served exactly once
            assert_eq!(x.batches.iter().map(|b| b.len).sum::<usize>(), 300, "{a:?}");
            assert!(x.latencies_s.iter().all(|&l| l > 0.0), "{a:?}");
        }
    }

    #[test]
    fn traced_sim_is_deterministic_and_transparent() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) };
        let dep = DeploymentSim {
            sims: sims(2, 1e-3),
            replicas: 2,
            switch_s: vec![5e-4, 5e-4],
            quantum_s: 0.0,
            cache: None,
        };
        let arr = Arrivals::Poisson { rate_hz: 700.0 };
        let plain = simulate_deployment(&arr, 150, 7, &policy, &dep);
        let mut ta = SimTrace::new();
        let mut tb = SimTrace::new();
        let a = simulate_deployment_traced(&arr, 150, 7, &policy, &dep, Some(&mut ta));
        let b = simulate_deployment_traced(&arr, 150, 7, &policy, &dep, Some(&mut tb));
        // recording spans must not perturb the simulation itself
        assert_eq!(a.latencies_s, plain.latencies_s);
        assert_eq!(a.batches, plain.batches);
        // and the spans themselves are seed-deterministic
        let ea = ta.into_events();
        assert_eq!(ea, tb.into_events());
        // lifecycle coverage: enqueue/wait/response per request, a flush
        // per batch, a swap per quantum, a stage span per request x stage
        let count = |k: SpanKind| ea.iter().filter(|e| e.kind == k).count();
        assert_eq!(count(SpanKind::Enqueue), 150);
        assert_eq!(count(SpanKind::Wait), 150);
        assert_eq!(count(SpanKind::Response), 150);
        assert_eq!(count(SpanKind::Flush), a.batches.len());
        assert_eq!(count(SpanKind::Swap), a.swaps);
        assert_eq!(count(SpanKind::Stage), 150 * 2);
    }

    #[test]
    fn overload_flushes_by_size_sparse_flushes_by_deadline() {
        let policy = BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(1) };
        let s = sims(2, 1e-3);
        // offered rate far above service rate: queues build, batches fill
        let hot = simulate_open_loop(&Arrivals::Poisson { rate_hz: 5000.0 }, 400, 1, &policy, &s);
        assert!(
            hot.flushes(FlushKind::Size) > hot.flushes(FlushKind::Deadline),
            "overload should mostly fill batches: {:?}",
            hot.batches.len()
        );
        // sparse arrivals: the wait deadline fires with tiny batches
        let cold = simulate_open_loop(&Arrivals::Poisson { rate_hz: 20.0 }, 50, 1, &policy, &s);
        assert!(
            cold.flushes(FlushKind::Deadline) + cold.flushes(FlushKind::Closed)
                > cold.flushes(FlushKind::Size),
            "sparse arrivals should flush by deadline"
        );
        // queueing delay must show up in the hot run's latencies
        let hot_mean = hot.latencies_s.iter().sum::<f64>() / hot.latencies_s.len() as f64;
        let cold_mean = cold.latencies_s.iter().sum::<f64>() / cold.latencies_s.len() as f64;
        assert!(hot_mean > cold_mean, "hot {hot_mean} vs cold {cold_mean}");
    }

    #[test]
    fn zero_max_wait_never_waits() {
        let policy = BatchPolicy { max_batch: 64, max_wait: Duration::ZERO };
        let s = sims(2, 5e-4);
        let run = simulate_open_loop(&Arrivals::Poisson { rate_hz: 300.0 }, 100, 9, &policy, &s);
        assert_eq!(run.batches.iter().map(|b| b.len).sum::<usize>(), 100);
        // with max_wait = 0 a batch only contains requests that had
        // already arrived when it opened: flush never exceeds open+0
        for b in &run.batches {
            assert!(b.len >= 1);
        }
    }

    #[test]
    fn replica_fanout_is_deterministic_and_cuts_queueing() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let s = sims(2, 1e-3);
        let hot = Arrivals::Poisson { rate_hz: 3000.0 };
        let one =
            simulate_deployment(&hot, 300, 5, &policy, &DeploymentSim::exclusive(s.clone()));
        let fan = DeploymentSim {
            sims: s,
            replicas: 2,
            switch_s: Vec::new(),
            quantum_s: 0.0,
            cache: None,
        };
        let two = simulate_deployment(&hot, 300, 5, &policy, &fan);
        let again = simulate_deployment(&hot, 300, 5, &policy, &fan);
        assert_eq!(two.latencies_s, again.latencies_s, "fan-out must stay deterministic");
        assert_eq!(two.batches, again.batches);
        assert_eq!(two.swaps, 0);
        assert_eq!(two.latencies_s.len(), 300);
        assert_eq!(two.batches.iter().map(|b| b.len).sum::<usize>(), 300);
        // a second pipeline drains an overloaded queue faster
        assert!(
            two.makespan_s < one.makespan_s,
            "2 replicas {} vs 1 {}",
            two.makespan_s,
            one.makespan_s
        );
        let mean =
            |r: &OpenLoopRun| r.latencies_s.iter().sum::<f64>() / r.latencies_s.len() as f64;
        assert!(mean(&two) < mean(&one));
    }

    #[test]
    fn shared_deployment_pays_swaps_per_batch_deterministically() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let s = sims(2, 1e-3);
        let arr = Arrivals::Poisson { rate_hz: 800.0 };
        let excl =
            simulate_deployment(&arr, 120, 9, &policy, &DeploymentSim::exclusive(s.clone()));
        assert_eq!(excl.swaps, 0);
        assert_eq!(excl.swap_overhead_s, 0.0);
        // a 1/2 slice: exec dilates 2x, and every flush re-loads both
        // stages' parameters at 3 ms each
        let dilated: Vec<StageSim> =
            s.iter().map(|x| StageSim { exec_s: 2.0 * x.exec_s, ..*x }).collect();
        let dep = DeploymentSim {
            sims: dilated,
            replicas: 1,
            switch_s: vec![3e-3; 2],
            quantum_s: 0.0,
            cache: None,
        };
        let shared = simulate_deployment(&arr, 120, 9, &policy, &dep);
        let again = simulate_deployment(&arr, 120, 9, &policy, &dep);
        assert_eq!(shared.latencies_s, again.latencies_s);
        assert_eq!(shared.swaps, again.swaps, "swap totals must be seed-deterministic");
        assert_eq!(shared.swaps, shared.batches.len(), "one swap per flushed batch");
        assert!(
            (shared.swap_overhead_s - shared.swaps as f64 * 6e-3).abs() < 1e-9,
            "{shared:?}"
        );
        let mean =
            |r: &OpenLoopRun| r.latencies_s.iter().sum::<f64>() / r.latencies_s.len() as f64;
        assert!(mean(&shared) > mean(&excl), "co-residency must cost latency");
    }

    #[test]
    fn cached_deployment_discounts_swaps_and_counts_them() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let dilated: Vec<StageSim> =
            sims(2, 1e-3).iter().map(|x| StageSim { exec_s: 2.0 * x.exec_s, ..*x }).collect();
        let arr = Arrivals::Poisson { rate_hz: 800.0 };
        let base = DeploymentSim {
            sims: dilated.clone(),
            replicas: 1,
            switch_s: vec![3e-3; 2],
            quantum_s: 0.0,
            cache: None,
        };
        let flat = simulate_deployment(&arr, 120, 9, &policy, &base);
        // cache off: the counters never move
        assert_eq!(flat.cache_hits, 0);
        assert_eq!(flat.cache_misses, 0);
        assert_eq!(flat.prefetches, 0);

        // a fully-warm effect pays only the compulsory first re-load
        let warm = DeploymentSim {
            cache: Some(CacheEffect { warm_frac: 1.0, prefetch_s: 0.0 }),
            ..base.clone()
        };
        let run = simulate_deployment(&arr, 120, 9, &policy, &warm);
        let again = simulate_deployment(&arr, 120, 9, &policy, &warm);
        assert_eq!(run.latencies_s, again.latencies_s, "cached sim must stay deterministic");
        assert_eq!(run.cache_hits, again.cache_hits);
        assert_eq!(run.cache_hits + run.cache_misses, run.swaps, "hits + misses == swaps");
        assert_eq!(run.cache_misses, 1, "only the first swap is a compulsory miss");
        assert!(
            (run.swap_overhead_s - 6e-3).abs() < 1e-12,
            "warm run pays exactly one cold re-load, got {}",
            run.swap_overhead_s
        );
        assert!(run.swap_overhead_s < flat.swap_overhead_s);
        let mean =
            |r: &OpenLoopRun| r.latencies_s.iter().sum::<f64>() / r.latencies_s.len() as f64;
        assert!(mean(&run) <= mean(&flat), "warm swaps must not cost latency");

        // an all-cold effect counts misses but reproduces the flat
        // timings bit-for-bit (frac is exactly 1.0 on every swap)
        let cold = DeploymentSim {
            cache: Some(CacheEffect { warm_frac: 0.0, prefetch_s: 0.0 }),
            ..base.clone()
        };
        let run = simulate_deployment(&arr, 120, 9, &policy, &cold);
        assert_eq!(run.latencies_s, flat.latencies_s);
        assert_eq!(run.swap_overhead_s, flat.swap_overhead_s);
        assert_eq!(run.cache_misses, run.swaps);
        assert_eq!(run.cache_hits, 0);
    }

    #[test]
    fn prefetch_spans_land_on_the_cache_track() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let dilated: Vec<StageSim> =
            sims(2, 1e-3).iter().map(|x| StageSim { exec_s: 2.0 * x.exec_s, ..*x }).collect();
        let arr = Arrivals::Poisson { rate_hz: 800.0 };
        let dep = DeploymentSim {
            sims: dilated,
            replicas: 1,
            switch_s: vec![3e-3; 2],
            quantum_s: 0.05,
            cache: Some(CacheEffect { warm_frac: 0.5, prefetch_s: 1e-3 }),
        };
        let mut tr = SimTrace::new();
        let run = simulate_deployment_traced(&arr, 120, 9, &policy, &dep, Some(&mut tr));
        assert_eq!(run.cache_hits + run.cache_misses, run.swaps);
        assert!(run.prefetches > 0, "non-first quantum swaps must prefetch");
        let events = tr.into_events();
        let pf: Vec<_> =
            events.iter().filter(|e| e.kind == SpanKind::Prefetch).collect();
        assert_eq!(pf.len(), run.prefetches, "one prefetch span per counted prefetch");
        assert!(
            pf.iter().all(|e| e.track == crate::obs::span::CACHE_TRACK),
            "prefetch spans must land on the cache track"
        );
    }

    #[test]
    fn larger_quantum_swaps_less_and_never_loses_throughput() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let dilated: Vec<StageSim> =
            sims(2, 1e-3).iter().map(|x| StageSim { exec_s: 2.0 * x.exec_s, ..*x }).collect();
        let arr = Arrivals::Poisson { rate_hz: 800.0 };
        let mut prev: Option<OpenLoopRun> = None;
        for quantum_s in [0.0, 0.05, 10.0] {
            let dep = DeploymentSim {
                sims: dilated.clone(),
                replicas: 1,
                switch_s: vec![3e-3; 2],
                quantum_s,
                cache: None,
            };
            let run = simulate_deployment(&arr, 120, 9, &policy, &dep);
            let again = simulate_deployment(&arr, 120, 9, &policy, &dep);
            assert_eq!(run.latencies_s, again.latencies_s, "quantum {quantum_s}");
            assert_eq!(run.swaps, again.swaps, "quantum {quantum_s}");
            if quantum_s == 0.0 {
                assert_eq!(run.swaps, run.batches.len(), "quantum 0 swaps every flush");
            }
            if let Some(p) = &prev {
                assert!(
                    run.swaps < p.swaps,
                    "larger quantum must swap less: {} -> {}",
                    p.swaps,
                    run.swaps
                );
                assert!(
                    run.throughput_hz() >= p.throughput_hz() - 1e-9,
                    "larger quantum must not lose throughput: {} -> {}",
                    p.throughput_hz(),
                    run.throughput_hz()
                );
            }
            prev = Some(run);
        }
        // a quantum so long it never expires pays exactly one swap
        assert_eq!(prev.unwrap().swaps, 1);
    }

    #[test]
    fn closed_loop_respects_concurrency() {
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
        let s = sims(2, 1e-3);
        let run = simulate_open_loop(
            &Arrivals::Closed { concurrency: 2, think_s: 0.0 },
            20,
            0,
            &policy,
            &s,
        );
        assert_eq!(run.latencies_s.len(), 20);
        assert_eq!(run.batches.iter().map(|b| b.len).sum::<usize>(), 20);
        // at most `concurrency` requests can ever share a batch
        assert!(run.batches.iter().all(|b| b.len <= 2), "{:?}", run.batches);
    }
}
