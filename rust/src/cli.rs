//! Command-line interface for the `repro` binary (hand-rolled: clap is not
//! in the offline vendor set).
//!
//! Commands map 1:1 to the paper's tables and figures — see DESIGN.md §4.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::config::SystemConfig;
use crate::report::{f2, ms, speedup, Table};
use crate::segment::strategy::Strategy;
use crate::sweep::{
    batch_sweep, headline, memory_rows, single_input_sweep, single_tpu_sweep, step_rows, Kind,
    MAX_TPUS,
};
use crate::util::fmt_macs;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`: first positional is the command, `--key value`
    /// (or `--key=value`) pairs follow; bare `--flag` means `"true"`.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut command = String::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                }
            } else if command.is_empty() {
                command = a.clone();
            } else {
                anyhow::bail!("unexpected positional argument {a:?}");
            }
            i += 1;
        }
        Ok(Args { command, flags })
    }

    pub fn kind(&self) -> Result<Kind> {
        match self.flags.get("kind").map(String::as_str) {
            None | Some("fc") => Ok(Kind::Fc),
            Some("conv") => Ok(Kind::Conv),
            Some(k) => anyhow::bail!("unknown --kind {k:?} (fc|conv)"),
        }
    }

    pub fn batch(&self) -> Result<usize> {
        self.usize_flag("batch", 50)
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("bad --{key} {v:?}")),
        }
    }

    /// `--key N` as u64 (seeds), falling back to `default`.
    pub fn u64_flag(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("bad --{key} {v:?}")),
        }
    }

    /// `--key X` as f64, falling back to `default`.
    pub fn f64_flag(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("bad --{key} {v:?}")),
        }
    }

    pub fn str_flag(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn csv(&self) -> bool {
        self.bool_flag("csv")
    }

    /// Bare `--flag` presence.
    pub fn bool_flag(&self, key: &str) -> bool {
        self.flags.get(key).map(String::as_str) == Some("true")
    }

    pub fn config(&self) -> Result<SystemConfig> {
        match self.flags.get("config") {
            None => Ok(SystemConfig::default()),
            Some(p) => SystemConfig::from_file(&PathBuf::from(p)),
        }
    }

    pub fn strategy(&self) -> Result<Strategy> {
        let batch = self.batch()?;
        match self.str_flag("strategy", "profiled").as_str() {
            "uniform" => Ok(Strategy::Uniform),
            "memory" => Ok(Strategy::MemoryBalanced),
            "profiled" => Ok(Strategy::ProfiledExhaustive { batch }),
            "threshold" => Ok(Strategy::ProfiledThreshold {
                batch,
                max_delta_s: self.flags.get("delta-ms").map(|v| v.parse::<f64>().unwrap_or(1.0) / 1e3).unwrap_or(1e-3),
            }),
            s => anyhow::bail!("unknown --strategy {s:?} (uniform|memory|profiled|threshold)"),
        }
    }
}

fn emit(table: Table, csv: bool) -> String {
    if csv {
        table.csv()
    } else {
        table.render()
    }
}

/// Fig 2a: inference time + memory vs #MACs for one family.
pub fn fig2a(kind: Kind, cfg: &SystemConfig, csv: bool) -> String {
    let mut t = Table::new(
        format!("Fig 2a ({}) — single-TPU inference time & memory", kind.label()),
        &["x", "macs", "time_ms", "device_mib", "host_mib"],
    );
    for p in single_tpu_sweep(kind, cfg) {
        t.row(vec![
            p.x.to_string(),
            p.macs.to_string(),
            ms(p.time_s),
            f2(p.device_mib),
            f2(p.host_mib),
        ]);
    }
    emit(t, csv)
}

/// Fig 2b: GOPS vs #MACs.
pub fn fig2b(kind: Kind, cfg: &SystemConfig, csv: bool) -> String {
    let mut t = Table::new(
        format!("Fig 2b ({}) — attained GOPS", kind.label()),
        &["x", "macs", "gops"],
    );
    for p in single_tpu_sweep(kind, cfg) {
        t.row(vec![p.x.to_string(), p.macs.to_string(), f2(p.gops)]);
    }
    emit(t, csv)
}

/// Fig 2c: TPU vs CPU inference time.
pub fn fig2c(kind: Kind, cfg: &SystemConfig, csv: bool) -> String {
    let mut t = Table::new(
        format!("Fig 2c ({}) — Edge TPU vs host CPU", kind.label()),
        &["x", "macs", "tpu_ms", "cpu_ms"],
    );
    for p in single_tpu_sweep(kind, cfg) {
        t.row(vec![p.x.to_string(), p.macs.to_string(), ms(p.time_s), ms(p.cpu_time_s)]);
    }
    emit(t, csv)
}

/// Tables I/II: memory + latency around each step.
pub fn table_steps(kind: Kind, cfg: &SystemConfig, csv: bool) -> String {
    let which = if kind == Kind::Fc { "Table I" } else { "Table II" };
    let mut t = Table::new(
        format!("{which} ({}) — before/after each host-memory step", kind.label()),
        &["step", "x", "#MACs", "device_mib", "host_mib", "time_ms"],
    );
    let pts = single_tpu_sweep(kind, cfg);
    for (i, (before, after)) in step_rows(&pts).iter().enumerate() {
        for p in [before, after] {
            t.row(vec![
                (i + 1).to_string(),
                p.x.to_string(),
                fmt_macs(p.macs),
                f2(p.device_mib),
                f2(p.host_mib),
                ms(p.time_s),
            ]);
        }
    }
    emit(t, csv)
}

/// Fig 4: single-input latency across 1..4 TPUs (default split).
pub fn fig4(kind: Kind, cfg: &SystemConfig, strategy: Strategy, csv: bool) -> String {
    let mut t = Table::new(
        format!(
            "Fig 4 ({}) — single-input inference time, 1..{MAX_TPUS} TPUs ({})",
            kind.label(),
            strategy.name()
        ),
        &["x", "macs", "t1_ms", "t2_ms", "t3_ms", "t4_ms"],
    );
    for p in single_input_sweep(kind, cfg, strategy) {
        let mut row = vec![p.x.to_string(), p.macs.to_string()];
        row.extend(p.per_s.iter().map(|&v| ms(v)));
        t.row(row);
    }
    emit(t, csv)
}

/// §V-B figure: batched speedups (vs single input / vs one TPU).
pub fn fig_batch(
    kind: Kind,
    cfg: &SystemConfig,
    strategy: Strategy,
    batch: usize,
    csv: bool,
) -> String {
    let mut t = Table::new(
        format!(
            "§V-B ({}) — {batch}-input batch speedups ({})",
            kind.label(),
            strategy.name()
        ),
        &[
            "x", "macs", "vs_single_s2", "vs_single_s3", "vs_single_s4", "vs_1tpu_s2",
            "vs_1tpu_s3", "vs_1tpu_s4",
        ],
    );
    for p in batch_sweep(kind, cfg, strategy, batch) {
        let mut row = vec![p.x.to_string(), p.macs.to_string()];
        row.extend(p.speedup_vs_single_input[1..].iter().map(|&v| speedup(v)));
        row.extend(p.speedup_vs_one_tpu[1..].iter().map(|&v| speedup(v)));
        t.row(row);
    }
    emit(t, csv)
}

/// Tables III–VI: per-device memory usage.
pub fn table_memory(
    kind: Kind,
    cfg: &SystemConfig,
    n_segments: usize,
    strategy: Strategy,
    xs: &[u64],
    title: &str,
    csv: bool,
) -> String {
    let mut headers: Vec<String> = vec!["x".into(), "#MACs".into(), "split".into()];
    for i in 1..=n_segments {
        headers.push(format!("dev{i}_mib"));
    }
    for i in 1..=n_segments {
        headers.push(format!("host{i}_mib"));
    }
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(title, &hrefs);
    for r in memory_rows(kind, cfg, n_segments, strategy, xs) {
        let mut row = vec![r.x.to_string(), fmt_macs(r.macs), r.label.clone()];
        row.extend(r.dev_mib.iter().map(|&v| f2(v)));
        row.extend(r.host_mib.iter().map(|&v| f2(v)));
        t.row(row);
    }
    emit(t, csv)
}

/// Fig 5: batched per-inference times with profiled splits.
pub fn fig5(kind: Kind, cfg: &SystemConfig, batch: usize, csv: bool) -> String {
    let strategy = Strategy::ProfiledExhaustive { batch };
    let mut t = Table::new(
        format!("Fig 5 ({}) — profiled splits, {batch}-input batch", kind.label()),
        &["x", "macs", "t1_ms", "t2_ms", "t3_ms", "t4_ms"],
    );
    for p in batch_sweep(kind, cfg, strategy, batch) {
        let mut row = vec![p.x.to_string(), p.macs.to_string()];
        row.extend(p.per_item_s.iter().map(|&v| ms(v)));
        t.row(row);
    }
    emit(t, csv)
}

/// Fig 6: speedups vs one TPU with profiled splits (headline figure).
pub fn fig6(kind: Kind, cfg: &SystemConfig, batch: usize, csv: bool) -> String {
    let strategy = Strategy::ProfiledExhaustive { batch };
    let mut t = Table::new(
        format!("Fig 6 ({}) — profiled speedup vs 1 TPU", kind.label()),
        &["x", "macs", "s2", "s3", "s4"],
    );
    for p in batch_sweep(kind, cfg, strategy, batch) {
        let mut row = vec![p.x.to_string(), p.macs.to_string()];
        row.extend(p.speedup_vs_one_tpu[1..].iter().map(|&v| speedup(v)));
        t.row(row);
    }
    let h = headline(kind, cfg, strategy, batch);
    let mut out = emit(t, csv);
    if !csv {
        out.push_str(&format!(
            "headline: {:.1}x at {}={} with {} TPUs (paper: {})\n",
            h.best_speedup,
            if kind == Kind::Fc { "n" } else { "f" },
            h.at_x,
            h.n_tpus,
            if kind == Kind::Fc { "46x" } else { "6x" },
        ));
    }
    out
}

/// Paper x-grids for Tables III–VI.
pub const TABLE3_XS: [u64; 7] = [1140, 1380, 1620, 1860, 2100, 2340, 2580];
pub const TABLE4_XS: [u64; 7] = [292, 352, 412, 472, 532, 592, 652];

/// Dispatch a parsed command; returns the rendered output.
pub fn run(args: &Args) -> Result<String> {
    let cfg = args.config()?;
    let csv = args.csv();
    let batch = args.batch()?;
    let out = match args.command.as_str() {
        "fig2a" => fig2a(args.kind()?, &cfg, csv),
        "fig2b" => fig2b(args.kind()?, &cfg, csv),
        "fig2c" => fig2c(args.kind()?, &cfg, csv),
        "table1" => table_steps(Kind::Fc, &cfg, csv),
        "table2" => table_steps(Kind::Conv, &cfg, csv),
        "fig4" => fig4(args.kind()?, &cfg, Strategy::Uniform, csv),
        "fig-batch" => fig_batch(args.kind()?, &cfg, Strategy::Uniform, batch, csv),
        "table3" => table_memory(
            Kind::Fc, &cfg, 2, Strategy::Uniform, &TABLE3_XS,
            "Table III (left) — FC, 2 segments, default split", csv,
        ),
        "table3b" => table_memory(
            Kind::Fc, &cfg, 3, Strategy::Uniform, &TABLE3_XS,
            "Table III (right) — FC, 3 segments, default split", csv,
        ),
        "table4" => table_memory(
            Kind::Conv, &cfg, 4, Strategy::Uniform, &TABLE4_XS,
            "Table IV — CONV, 4 segments, default split", csv,
        ),
        "table5" => table_memory(
            Kind::Fc, &cfg, 3, Strategy::ProfiledExhaustive { batch }, &TABLE3_XS,
            "Table V — FC, 3 segments, profiled split", csv,
        ),
        "table6" => table_memory(
            Kind::Conv, &cfg, 4, Strategy::ProfiledExhaustive { batch }, &TABLE4_XS,
            "Table VI — CONV, 4 segments, profiled split", csv,
        ),
        "fig5" => fig5(args.kind()?, &cfg, batch, csv),
        "fig6" => fig6(args.kind()?, &cfg, batch, csv),
        "headline" => {
            let mut s = String::new();
            for kind in [Kind::Fc, Kind::Conv] {
                for (name, strat) in [
                    ("uniform", Strategy::Uniform),
                    ("profiled", Strategy::ProfiledExhaustive { batch }),
                ] {
                    let h = headline(kind, &cfg, strat, batch);
                    s.push_str(&format!(
                        "{:4} {:9}: {:5.1}x at x={} ({} TPUs)\n",
                        kind.label(),
                        name,
                        h.best_speedup,
                        h.at_x,
                        h.n_tpus
                    ));
                }
            }
            s
        }
        "all" => {
            let mut s = String::new();
            for kind in [Kind::Fc, Kind::Conv] {
                s.push_str(&fig2a(kind, &cfg, csv));
                s.push('\n');
                s.push_str(&fig2b(kind, &cfg, csv));
                s.push('\n');
                s.push_str(&fig2c(kind, &cfg, csv));
                s.push('\n');
                s.push_str(&fig4(kind, &cfg, Strategy::Uniform, csv));
                s.push('\n');
                s.push_str(&fig_batch(kind, &cfg, Strategy::Uniform, batch, csv));
                s.push('\n');
                s.push_str(&fig5(kind, &cfg, batch, csv));
                s.push('\n');
                s.push_str(&fig6(kind, &cfg, batch, csv));
                s.push('\n');
            }
            for c in ["table1", "table2", "table3", "table3b", "table4", "table5", "table6"] {
                let sub = Args { command: c.to_string(), flags: args.flags.clone() };
                s.push_str(&run(&sub)?);
                s.push('\n');
            }
            s
        }
        "ablation-replicate" => ablation_replicate(args.kind()?, &cfg, batch),
        "ablation-hybrid" => ablation_hybrid(&cfg, batch),
        "ablation-energy" => ablation_energy(args.kind()?, &cfg, batch),
        "schedule" => schedule(args)?,
        "loadgen" => loadgen(args)?,
        "dataplane" => dataplane(args)?,
        "chaos" => chaos(args)?,
        "recover" => recover_cmd(args)?,
        "calibrate" => calibrate(args)?,
        "trace" => trace_cmd(args)?,
        "" | "help" | "--help" => USAGE.to_string(),
        other => anyhow::bail!("unknown command {other:?}\n{USAGE}"),
    };
    Ok(out)
}

/// Parse the shared pool flags — `--models`, `--weights`, `--slo-ms`,
/// `--tpus`, `--batch`, `--max-tpus-per-model`, `--allow-spill`,
/// `--no-replicas`, `--allow-sharing`, `--switch-cost-us`,
/// `--max-residents`, `--quantum-us`, `--cache-budget-bytes`,
/// `--prefetch` — into a registry + allocator config.  Shared by
/// `repro schedule`, `repro serve-pool` and `repro loadgen` so planning
/// and deployment always see the same tenancy spec.
pub fn pool_spec(
    args: &Args,
    default_models: &str,
) -> Result<(crate::scheduler::ModelRegistry, crate::scheduler::AllocatorConfig)> {
    use crate::scheduler::{AllocatorConfig, ModelRegistry, Tenant};

    let models = args.str_flag("models", default_models);
    let names: Vec<&str> =
        models.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    anyhow::ensure!(!names.is_empty(), "--models must name at least one model");

    let weights: Vec<f64> = match args.flags.get("weights") {
        None => vec![1.0; names.len()],
        Some(spec) => {
            let ws: Vec<f64> = spec
                .split(',')
                .map(|w| w.trim().parse().with_context(|| format!("bad --weights {spec:?}")))
                .collect::<Result<_>>()?;
            anyhow::ensure!(
                ws.len() == names.len() && ws.iter().all(|&w| w > 0.0),
                "--weights needs one positive value per model"
            );
            ws
        }
    };
    let slos_ms: Vec<Option<f64>> = match args.flags.get("slo-ms") {
        None => vec![None; names.len()],
        Some(spec) => {
            let ss: Vec<Option<f64>> = spec
                .split(',')
                .map(|s| {
                    let s = s.trim();
                    if s.is_empty() || s == "-" {
                        Ok(None)
                    } else {
                        s.parse().map(Some).with_context(|| format!("bad --slo-ms {spec:?}"))
                    }
                })
                .collect::<Result<_>>()?;
            anyhow::ensure!(
                ss.len() == names.len(),
                "--slo-ms needs one value (or '-') per model"
            );
            ss
        }
    };

    let mut registry = ModelRegistry::new();
    for (i, name) in names.iter().enumerate() {
        let model = crate::scheduler::resolve_model(name)?;
        let mut tenant = Tenant::new(*name, model).with_weight(weights[i]);
        if let Some(slo_ms) = slos_ms[i] {
            tenant = tenant.with_slo_p99_s(slo_ms / 1e3);
        }
        registry.register(tenant)?;
    }

    let switch_cost_us = match args.flags.get("switch-cost-us") {
        None => None,
        Some(v) => {
            let us: f64 =
                v.parse().with_context(|| format!("bad --switch-cost-us {v:?}"))?;
            anyhow::ensure!(
                us.is_finite(),
                "--switch-cost-us must be a finite number of microseconds (got {us})"
            );
            anyhow::ensure!(us >= 0.0, "--switch-cost-us must be non-negative (got {us})");
            Some(us)
        }
    };
    let quantum_us = args.f64_flag("quantum-us", 0.0)?;
    anyhow::ensure!(
        quantum_us.is_finite(),
        "--quantum-us must be a finite number of microseconds (got {quantum_us})"
    );
    anyhow::ensure!(quantum_us >= 0.0, "--quantum-us must be non-negative");
    if let Some(v) = args.flags.get("cache-budget-bytes") {
        anyhow::ensure!(
            !v.trim().starts_with('-'),
            "--cache-budget-bytes must be non-negative (got {v})"
        );
    }
    // one validated construction path for every planner-facing command
    // (schedule / serve-pool / loadgen / dataplane / chaos / calibrate):
    // the builder re-checks the cross-knob invariants the per-flag guards
    // above cannot see (e.g. sharing needs max_residents >= 2)
    let mut b = AllocatorConfig::builder()
        .total_tpus(args.usize_flag("tpus", 4)?)
        .batch(args.batch()?)
        .max_tpus_per_model(args.usize_flag("max-tpus-per-model", 4)?)
        .allow_host_spill(args.bool_flag("allow-spill"))
        .replicate_leftover(!args.bool_flag("no-replicas"))
        .allow_sharing(args.bool_flag("allow-sharing"))
        .max_residents(args.usize_flag("max-residents", 2)?)
        .quantum_us(quantum_us)
        .cache_budget_bytes(args.u64_flag("cache-budget-bytes", 0)?)
        .prefetch(args.bool_flag("prefetch"));
    if let Some(us) = switch_cost_us {
        b = b.switch_cost_us(us);
    }
    let alloc = b.build()?;
    Ok((registry, alloc))
}

/// `repro schedule`: multi-tenant TPU-pool admission + placement table.
///
/// Pure cost-model simulation (no artifacts needed): registers the named
/// models, runs the pool allocator, and prints per-model
/// `(tpu_count, strategy, predicted p99)` plus queued/rejected tenants.
/// With `--allow-sharing`, plans computed under time-multiplexed
/// co-residency add the grant + swap-overhead columns; tenants with an
/// SLO additionally get their derived batch policy printed (the flush
/// deadline shrinks under tight SLOs).
pub fn schedule(args: &Args) -> Result<String> {
    use crate::coordinator::batcher::BatchPolicy;
    use crate::scheduler::{allocate, plan_table};

    let cfg = args.config()?;
    let (registry, alloc) = pool_spec(args, "fc_big,conv_a,conv_b")?;
    let plan = allocate(&registry, &cfg, &alloc)?;
    let mut out = emit(plan_table(&plan), args.csv());
    if !args.csv() {
        out.push_str(&format!(
            "pool: {}/{} TPUs used | weighted p99 objective {} ms | \
             admitted {} queued {} rejected {}{}\n",
            plan.tpus_used(),
            plan.total_tpus,
            ms(plan.objective_s),
            plan.assignments.len(),
            plan.queued.len(),
            plan.rejected.len(),
            if plan.sharing_enabled {
                let quantum = if alloc.quantum_us > 0.0 {
                    format!(" (quantum {} us)", alloc.quantum_us)
                } else {
                    String::new()
                };
                let cache = if plan.cache_enabled {
                    format!(
                        " | cache budget {} B{}",
                        alloc.cache_budget_bytes,
                        if alloc.prefetch { " + prefetch" } else { "" },
                    )
                } else {
                    String::new()
                };
                format!(" shared {}{}{}", plan.shared_count(), quantum, cache)
            } else {
                String::new()
            },
        ));
        // per-tenant batch policies derived from SLOs (only rendered when
        // an admitted tenant declared an SLO, so SLO-free invocations are
        // unchanged; queued/rejected tenants have no deployment to batch)
        let with_slo: Vec<_> = registry
            .iter()
            .filter(|t| t.slo_p99_s.is_some() && plan.assignment(&t.name).is_some())
            .collect();
        if !with_slo.is_empty() {
            let base = BatchPolicy {
                max_batch: args.usize_flag("max-batch", 8)?,
                max_wait: std::time::Duration::from_secs_f64(
                    args.f64_flag("max-wait-ms", 2.0)? / 1e3,
                ),
            };
            for t in with_slo {
                let p = base.for_slo(t.slo_p99_s);
                out.push_str(&format!(
                    "batch policy {}: max_batch {} max_wait {} \
                     (slo {}, pool max_wait {})\n",
                    t.name,
                    p.max_batch,
                    ms(p.max_wait.as_secs_f64()),
                    ms(t.slo_p99_s.unwrap_or(f64::NAN)),
                    ms(base.max_wait.as_secs_f64()),
                ));
            }
        }
    }
    Ok(out)
}

/// Parsed `repro loadgen` inputs beyond the shared pool flags.
#[derive(Debug, Clone)]
pub struct LoadgenSpec {
    /// One offered load per registered model, in `--models` order.
    pub loads: Vec<crate::workload::TenantLoad>,
    /// Run seed: drives arrival schedules and request payloads.
    pub seed: u64,
    /// Per-tenant dynamic batching policy.
    pub policy: crate::coordinator::batcher::BatchPolicy,
}

/// Parse the `repro loadgen` flags: the shared pool flags (`--models`,
/// `--tpus`, `--weights`, `--slo-ms`, `--allow-sharing`, ...) plus
/// `--seed`, `--requests` (per tenant), `--arrivals` (one spec, or one
/// per model, comma-joined) and the base batch policy (`--max-batch`,
/// `--max-wait-ms`); tenants with an SLO get a derived per-tenant policy
/// (`BatchPolicy::for_slo`), applied identically by the deterministic
/// simulation and the live pool.
///
/// Replica grants are planned normally: the deterministic simulation
/// models the round-robin fan-out, so data-parallel deployments are
/// covered too (`--no-replicas` restores the old single-pipeline plans).
pub fn loadgen_spec(
    args: &Args,
) -> Result<(crate::scheduler::ModelRegistry, crate::scheduler::AllocatorConfig, LoadgenSpec)> {
    use crate::coordinator::batcher::BatchPolicy;
    use crate::workload::{Arrivals, TenantLoad};

    const DEFAULT_MODELS: &str = "fc_small,conv_a";
    let (registry, alloc) = pool_spec(args, DEFAULT_MODELS)?;

    let models = args.str_flag("models", DEFAULT_MODELS);
    let names: Vec<&str> =
        models.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();

    let seed = args.u64_flag("seed", 7)?;
    let requests = args.usize_flag("requests", 200)?;
    anyhow::ensure!(requests >= 1, "--requests must be at least 1");

    let arrivals_flag = args.str_flag("arrivals", "poisson:400");
    let specs: Vec<&str> =
        arrivals_flag.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    anyhow::ensure!(
        specs.len() == 1 || specs.len() == names.len(),
        "--arrivals needs one spec or one per model (got {} for {} models)",
        specs.len(),
        names.len()
    );

    let loads = names
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let spec = if specs.len() == 1 { specs[0] } else { specs[i] };
            Ok(TenantLoad {
                model: (*name).to_string(),
                arrivals: Arrivals::parse(spec)?,
                requests,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let max_wait_ms = args.f64_flag("max-wait-ms", 2.0)?;
    anyhow::ensure!(max_wait_ms >= 0.0, "--max-wait-ms must be non-negative");
    let policy = BatchPolicy {
        max_batch: args.usize_flag("max-batch", 8)?,
        max_wait: std::time::Duration::from_secs_f64(max_wait_ms / 1e3),
    };
    anyhow::ensure!(policy.max_batch >= 1, "--max-batch must be at least 1");

    Ok((registry, alloc, LoadgenSpec { loads, seed, policy }))
}

/// Build the deterministic `repro loadgen` table: per tenant, push the
/// seeded arrival schedule through the open-loop queueing simulation
/// (batcher flush rules + pipeline recurrence on the planned deployment,
/// including replica fan-out and shared-grant swap costs) and report
/// offered rate, batch/flush/swap counters, latency percentiles and
/// throughput.  Pure function of `(registry, cfg, alloc, spec)` — two
/// calls render bit-identical tables, which is the reproducibility
/// contract of `repro loadgen`.
pub fn loadgen_table(
    registry: &crate::scheduler::ModelRegistry,
    cfg: &SystemConfig,
    alloc: &crate::scheduler::AllocatorConfig,
    spec: &LoadgenSpec,
) -> Result<(Table, crate::scheduler::PoolPlan)> {
    let (t, plan, _) = loadgen_table_obs(registry, cfg, alloc, spec)?;
    Ok((t, plan))
}

/// One admitted tenant's deterministic observability artifacts from a
/// loadgen run: the simulated span events (tenant-local tracks, see
/// `obs::span::track_base`), the replica/stage shape needed to name the
/// tracks, and the metric snapshot pre-rendered as a JSONL line.
pub struct LoadgenTenantObs {
    pub model: String,
    pub replicas: usize,
    pub n_stages: usize,
    /// Whether the tenant's grant carries a parameter-cache effect (names
    /// the `{model}/cache` prefetch track in the exported trace).
    pub cache: bool,
    pub events: Vec<crate::obs::SpanEvent>,
    pub metrics_line: String,
}

/// [`loadgen_table`] plus per-tenant span traces and metric lines.  All
/// three outputs are pure functions of `(registry, cfg, alloc, spec)`, so
/// the `--trace-out` / `--metrics-out` files diff clean across two runs
/// of one seed — the contract `make smoke-trace` enforces.
pub fn loadgen_table_obs(
    registry: &crate::scheduler::ModelRegistry,
    cfg: &SystemConfig,
    alloc: &crate::scheduler::AllocatorConfig,
    spec: &LoadgenSpec,
) -> Result<(Table, crate::scheduler::PoolPlan, Vec<LoadgenTenantObs>)> {
    use crate::metrics::FlushKind;
    use crate::obs::{metric_line_from, num, SimTrace};
    use crate::scheduler::allocate;
    use crate::util::json::Json;
    use crate::util::stats::{LatencyHistogram, Summary};
    use crate::workload::{arrival_seed, simulate_deployment_traced};

    let plan = allocate(registry, cfg, alloc)?;
    let mut obs: Vec<LoadgenTenantObs> = Vec::new();
    // cache-enabled plans grow four columns after swap_over_ms; with a
    // zero budget the header (and every row) is byte-identical to today
    let mut headers = vec![
        "model", "arrivals", "offered_hz", "requests", "tpus", "replicas", "split",
        "grant", "quantum_us", "batches", "flush_size", "flush_deadline",
        "flush_closed", "swaps", "swap_over_ms",
    ];
    if plan.cache_enabled {
        headers.extend(["cache_hits", "cache_misses", "prefetches", "hit_rate"]);
    }
    headers.extend([
        "p50_ms", "p99_ms", "mean_ms", "throughput_hz", "max_wait_ms", "status",
    ]);
    let mut t = Table::new(
        format!(
            "Open-loop load generation — seed {} | max_batch {} | max_wait {} ms",
            spec.seed,
            spec.policy.max_batch,
            spec.policy.max_wait.as_secs_f64() * 1e3,
        ),
        &headers,
    );
    for load in &spec.loads {
        let offered = match load.arrivals.offered_rate_hz() {
            Some(r) => format!("{r:.1}"),
            None => "-".into(),
        };
        let Some(a) = plan.assignment(&load.model) else {
            let status = if plan.rejected.iter().any(|r| r.name == load.model) {
                "rejected"
            } else {
                "queued"
            };
            let mut row = vec![
                load.model.clone(),
                load.arrivals.label(),
                offered,
                load.requests.to_string(),
            ];
            row.extend(vec![
                "-".to_string();
                16 + if plan.cache_enabled { 4 } else { 0 }
            ]);
            row.push(status.into());
            t.row(row);
            continue;
        };
        let tenant = registry.get(&load.model)?;
        // a tight SLO shrinks this tenant's flush deadline — the same
        // derivation the live pool applies
        let policy = spec.policy.for_slo(tenant.slo_p99_s);
        let dep = crate::serving::deployment_sim(tenant, a, cfg);
        let mut sim_trace = SimTrace::new();
        let run = simulate_deployment_traced(
            &load.arrivals,
            load.requests,
            arrival_seed(spec.seed, &load.model),
            &policy,
            &dep,
            Some(&mut sim_trace),
        );
        // exact percentiles for the table; the exported metric line uses
        // the streaming histogram (what the live path keeps at O(1) mem)
        let mut lat = Summary::new();
        let mut hist = LatencyHistogram::new();
        for &l in &run.latencies_s {
            lat.add(l);
            hist.record(l);
        }
        let mut fields = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            fields.insert(k.to_string(), v);
        };
        put("requests", Json::Num(run.latencies_s.len() as f64));
        put("batches", Json::Num(run.batches.len() as f64));
        put("flush_size", Json::Num(run.flushes(FlushKind::Size) as f64));
        put("flush_deadline", Json::Num(run.flushes(FlushKind::Deadline) as f64));
        put("flush_closed", Json::Num(run.flushes(FlushKind::Closed) as f64));
        put("swaps", Json::Num(run.swaps as f64));
        put("swap_overhead_s", num(run.swap_overhead_s));
        if plan.cache_enabled {
            put("cache_hits", Json::Num(run.cache_hits as f64));
            put("cache_misses", Json::Num(run.cache_misses as f64));
            put("prefetches", Json::Num(run.prefetches as f64));
        }
        put("p50_s", num(hist.percentile(50.0)));
        put("p99_s", num(hist.percentile(99.0)));
        put("p999_s", num(hist.percentile(99.9)));
        put("mean_s", num(hist.mean()));
        put("throughput_hz", num(run.throughput_hz()));
        obs.push(LoadgenTenantObs {
            model: load.model.clone(),
            replicas: a.replicas,
            n_stages: a.candidate.partition.n_segments(),
            cache: a.grant.cache().is_some(),
            events: sim_trace.into_events(),
            metrics_line: metric_line_from("loadgen", &load.model, Json::Obj(fields)),
        });
        let mut row = vec![
            load.model.clone(),
            load.arrivals.label(),
            offered,
            load.requests.to_string(),
            a.candidate.tpu_count.to_string(),
            a.replicas.to_string(),
            a.candidate.partition.label(),
            a.grant.label(),
            format!("{:.0}", a.grant.quantum_s() * 1e6),
            run.batches.len().to_string(),
            run.flushes(FlushKind::Size).to_string(),
            run.flushes(FlushKind::Deadline).to_string(),
            run.flushes(FlushKind::Closed).to_string(),
            run.swaps.to_string(),
            ms(run.swap_overhead_s),
        ];
        if plan.cache_enabled {
            row.push(run.cache_hits.to_string());
            row.push(run.cache_misses.to_string());
            row.push(run.prefetches.to_string());
            row.push(if run.swaps > 0 {
                format!("{:.0}%", 100.0 * run.cache_hits as f64 / run.swaps as f64)
            } else {
                "-".to_string()
            });
        }
        row.extend([
            ms(lat.p50()),
            ms(lat.p99()),
            ms(lat.mean()),
            format!("{:.1}", run.throughput_hz()),
            ms(policy.max_wait.as_secs_f64()),
            "admitted".into(),
        ]);
        t.row(row);
    }
    Ok((t, plan, obs))
}

/// Assemble per-tenant sim traces into one Chrome-trace file: tenant `i`'s
/// local tracks shift onto the global run starting at
/// `obs::span::track_base(i)`, and every track gets its viewer name
/// (`model/requests`, `model/batcher`, `model/rep{r}/stage{s}`).
pub fn loadgen_trace_file(obs: &[LoadgenTenantObs]) -> crate::obs::TraceFile {
    use crate::obs::span::track_base;

    let mut file = crate::obs::TraceFile::new("repro loadgen");
    for (idx, o) in obs.iter().enumerate() {
        let base = track_base(idx);
        file.name_track(base, format!("{}/requests", o.model));
        file.name_track(base + 1, format!("{}/batcher", o.model));
        for rep in 0..o.replicas {
            for s in 0..o.n_stages {
                let t = base + 2 + (rep * o.n_stages + s) as u32;
                file.name_track(t, format!("{}/rep{rep}/stage{s}", o.model));
            }
        }
        if o.cache {
            file.name_track(
                base + crate::obs::span::CACHE_TRACK,
                format!("{}/cache", o.model),
            );
        }
        for e in &o.events {
            let mut e = *e;
            e.track += base;
            file.events.push(e);
        }
    }
    file.events.sort_by_key(|e| (e.start_us, e.track, e.id));
    file
}

/// The loadgen metrics export: one JSONL line per admitted tenant.
pub fn loadgen_metrics_jsonl(obs: &[LoadgenTenantObs]) -> String {
    obs.iter().map(|o| o.metrics_line.as_str()).collect()
}

/// Write the `--trace-out` / `--metrics-out` files of a loadgen run (a
/// no-op without the flags).  Both files come from the deterministic
/// simulation, so two runs of one seed write byte-identical bytes.
pub fn write_loadgen_exports(args: &Args, obs: &[LoadgenTenantObs]) -> Result<()> {
    if let Some(path) = args.flags.get("trace-out") {
        std::fs::write(path, loadgen_trace_file(obs).to_json())
            .with_context(|| format!("writing --trace-out {path:?}"))?;
    }
    if let Some(path) = args.flags.get("metrics-out") {
        std::fs::write(path, loadgen_metrics_jsonl(obs))
            .with_context(|| format!("writing --metrics-out {path:?}"))?;
    }
    Ok(())
}

/// One-line pool summary appended under the (non-CSV) loadgen table.
pub fn loadgen_summary(plan: &crate::scheduler::PoolPlan) -> String {
    format!(
        "pool: {}/{} TPUs used | admitted {}{} queued {} rejected {} | \
         same --seed => bit-identical table\n",
        plan.tpus_used(),
        plan.total_tpus,
        plan.assignments.len(),
        if plan.sharing_enabled {
            format!(" (shared {})", plan.shared_count())
        } else {
            String::new()
        },
        plan.queued.len(),
        plan.rejected.len(),
    )
}

/// `repro loadgen` (deterministic part): render the seeded open-loop
/// table.  The binary's `loadgen` command prints this and then (unless
/// `--csv` or `--no-live`) drives the same seeds through the live
/// `ServingPool` with bit-exact response verification.
pub fn loadgen(args: &Args) -> Result<String> {
    let cfg = args.config()?;
    let (registry, alloc, spec) = loadgen_spec(args)?;
    let (table, plan, obs) = loadgen_table_obs(&registry, &cfg, &alloc, &spec)?;
    write_loadgen_exports(args, &obs)?;
    let mut out = emit(table, args.csv());
    if !args.csv() {
        out.push_str(&loadgen_summary(&plan));
    }
    // --calibrate appends the calibration report *after* the unchanged
    // loadgen output, so flag-off runs stay byte-identical
    if let Some(report) = loadgen_calibration(args, &registry, &cfg, &alloc, &spec)? {
        out.push_str(&report);
    }
    Ok(out)
}

/// `repro dataplane`: the zero-copy data-plane smoke — drive live
/// deployments (closed-batch router, then open-loop pool), measure the
/// arena's allocation counters after a warm-up phase, and **fail** when
/// steady-state allocations-per-request exceed `--alloc-budget`
/// (default 0: the warm data plane must not allocate at all).  Every
/// response is verified bit-for-bit against the serial reference, so the
/// gate also re-proves byte-determinism of the batched path.
///
/// For the deployments this gate runs against in CI (single pipelines,
/// and replicas of single-stage pipelines) both phases are deterministic
/// by construction: the closed phase serves fixed-size batches
/// back-to-back (replica shards are packed in the caller thread, so the
/// arena sees the full fan-out demand on every call), and the open phase
/// keeps exactly one request outstanding per tenant — slab sizes repeat
/// exactly and the warm-up provably covers the measured window.  A
/// *multi-stage replicated* deployment is the one shape whose
/// intermediate-slab overlap is thread-timing-dependent; gate such
/// topologies with a small nonzero `--alloc-budget` instead of 0.
pub fn dataplane(args: &Args) -> Result<String> {
    use crate::coordinator::batcher::BatchPolicy;
    use crate::metrics::DataPlaneSnapshot;
    use crate::obs::{metric_line_from, MetricSource, TraceFile, Tracer};
    use crate::scheduler::{allocate, BackendKind, DeployOptions, PoolRouter, ServingPool};
    use crate::util::json::Json;
    use std::sync::Arc;

    let cfg = args.config()?;
    let (registry, alloc) = pool_spec(args, "fc_small,conv_a")?;
    let batch = args.batch()?;
    let warmup = args.usize_flag("warmup", 3)?.max(1);
    let iters = args.usize_flag("iters", 5)?.max(1);
    let open_warmup = args.usize_flag("open-warmup", 40)?.max(1);
    let open_requests = args.usize_flag("open-requests", 80)?.max(1);
    let budget = args.f64_flag("alloc-budget", 0.0)?;
    anyhow::ensure!(budget >= 0.0, "--alloc-budget must be non-negative");

    let mut t = Table::new(
        format!(
            "Zero-copy data plane — steady-state alloc budget {budget} per request \
             (closed batch {batch} x{iters}, open loop {open_requests} reqs)"
        ),
        &[
            "phase", "model", "requests", "allocs", "allocs_per_req", "reuses",
            "handoffs", "items_per_handoff", "status",
        ],
    );
    let mut failures: Vec<String> = Vec::new();
    let mut row = |phase: &str,
                   model: &str,
                   requests: u64,
                   before: DataPlaneSnapshot,
                   after: DataPlaneSnapshot,
                   failures: &mut Vec<String>| {
        let allocs = after.slab_allocs - before.slab_allocs;
        let per_req = allocs as f64 / requests as f64;
        let handoffs = after.handoffs - before.handoffs;
        let items = after.handoff_items - before.handoff_items;
        let ok = per_req <= budget + 1e-12;
        if !ok {
            failures.push(format!(
                "{phase}/{model}: {allocs} steady-state allocations over {requests} \
                 requests ({per_req:.4}/req > budget {budget})"
            ));
        }
        t.row(vec![
            phase.to_string(),
            model.to_string(),
            requests.to_string(),
            allocs.to_string(),
            format!("{per_req:.4}"),
            (after.slab_reuses - before.slab_reuses).to_string(),
            handoffs.to_string(),
            if handoffs == 0 {
                "-".into()
            } else {
                format!("{:.1}", items as f64 / handoffs as f64)
            },
            if ok { "PASS".into() } else { "FAIL".into() },
        ]);
    };

    // live span tracer, only when asked for: the default (None) path is
    // what the zero-alloc budget gate measures
    let tracer: Option<Arc<Tracer>> =
        args.flags.contains_key("trace-out").then(|| Arc::new(Tracer::new()));
    // end-of-run metric snapshots, uniformly via MetricSource: rendered as
    // the human table below and (with --metrics-out) written as JSONL
    let mut metrics_out: Vec<(String, String, Json)> = Vec::new();

    // ---- phase 1: closed batches through the per-model router
    let plan = allocate(&registry, &cfg, &alloc)?;
    let mut router_opts = DeployOptions::new().with_queue_capacity(64);
    if let Some(t) = tracer.clone() {
        router_opts = router_opts.with_tracer(t);
    }
    let router =
        PoolRouter::deploy(&plan, &registry, &cfg, &BackendKind::Synthetic, router_opts)?;
    router.wait_ready()?;
    for name in router.names() {
        let tenant = router.tenant(&name).expect("deployed tenant");
        let serve_once = |seed: u64| -> Result<()> {
            let reqs = tenant.synth_requests(batch, seed);
            let expected: Vec<Vec<i8>> =
                reqs.iter().map(|r| tenant.reference(&r.data)).collect();
            let responses = router.serve(&name, reqs)?;
            for (r, e) in responses.iter().zip(&expected) {
                anyhow::ensure!(&r.data == e, "{name}: digest mismatch on {}", r.id);
            }
            Ok(())
        };
        for i in 0..warmup {
            serve_once(i as u64)?;
        }
        let before = router.data_plane.snapshot();
        for i in 0..iters {
            serve_once(1000 + i as u64)?;
        }
        let after = router.data_plane.snapshot();
        row("closed", &name, (iters * batch) as u64, before, after, &mut failures);
    }
    let dp = &*router.data_plane;
    metrics_out.push((dp.metric_kind().to_string(), "router".to_string(), dp.metric_json()));
    router.shutdown();

    // ---- phase 2: live open-loop pool, one request outstanding
    let pool = ServingPool::deploy(
        registry,
        cfg,
        alloc,
        BackendKind::Synthetic,
        DeployOptions {
            policy: BatchPolicy {
                max_batch: args.usize_flag("max-batch", 8)?,
                max_wait: std::time::Duration::from_micros(500),
            },
            queue_capacity: 64,
            tracer: tracer.clone(),
            ..Default::default()
        },
    )?;
    for name in pool.names() {
        let client = pool.client(&name)?;
        let serve_one = |seed: u64| -> Result<()> {
            let mut reqs = client.synth_requests(1, seed);
            let req = reqs.pop().expect("one request");
            let expected = client.reference(&req.data);
            pool.submit(&name, req)?;
            let resp = client.done.recv().context("completion stream closed early")?;
            anyhow::ensure!(resp.data == expected, "{name}: open-loop digest mismatch");
            Ok(())
        };
        for i in 0..open_warmup {
            serve_one(i as u64)?;
        }
        let before = pool.data_plane().snapshot();
        for i in 0..open_requests {
            serve_one(10_000 + i as u64)?;
        }
        let after = pool.data_plane().snapshot();
        row("open", &name, open_requests as u64, before, after, &mut failures);
    }
    for name in pool.names() {
        if let Some(m) = pool.tenant_metrics(&name) {
            metrics_out.push((m.metric_kind().to_string(), name.clone(), m.metric_json()));
        }
    }
    let dp = pool.data_plane();
    metrics_out.push((dp.metric_kind().to_string(), "pool".to_string(), dp.metric_json()));
    let sched = &*pool.metrics;
    metrics_out.push((sched.metric_kind().to_string(), "pool".to_string(), sched.metric_json()));
    pool.shutdown();

    // exports are written even when the budget gate fails below: the
    // trace is exactly what you want for diagnosing the failure
    if let Some(path) = args.flags.get("metrics-out") {
        let jsonl: String = metrics_out
            .iter()
            .map(|(k, n, j)| metric_line_from(k, n, j.clone()))
            .collect();
        std::fs::write(path, jsonl)
            .with_context(|| format!("writing --metrics-out {path:?}"))?;
    }
    if let (Some(path), Some(tr)) = (args.flags.get("trace-out"), &tracer) {
        std::fs::write(path, TraceFile::from_tracer("repro dataplane", tr).to_json())
            .with_context(|| format!("writing --trace-out {path:?}"))?;
    }

    let mut out = t.render();
    out.push_str(&crate::report::metrics_table(&metrics_out).render());
    if failures.is_empty() {
        out.push_str("data plane: steady state within the allocation budget\n");
        Ok(out)
    } else {
        print!("{out}");
        anyhow::bail!("data-plane alloc budget exceeded: {}", failures.join("; "))
    }
}

/// `repro chaos`: the deterministic fault-injection suite (DESIGN.md §14).
///
/// Sim mode (the default) draws a seeded `FaultPlan` per tenant —
/// device kills, straggler windows, overload spikes — and replays it
/// through the deterministic chaos queueing sim: the table (and its
/// `--csv` form) is a pure function of the flags, so two runs of one seed
/// are byte-identical, which is the golden artifact `make smoke-chaos`
/// diffs.  Accounting invariants are enforced on every row: offered =
/// admitted + shed, and every admitted request completes.
///
/// `--live` then walks the same fault kinds against a real `ServingPool`
/// on the synthetic backend: a baseline bit-exact round trip, an injected
/// replica straggler (hedged dispatch), a tiered overload burst
/// (admission shedding with exact accounting), and a mid-run
/// `kill_device` (re-plan + drain replay) — every admitted response is
/// verified bit-for-bit against the serial reference throughout, and the
/// command fails if any phase drops or corrupts a request.
pub fn chaos(args: &Args) -> Result<String> {
    use crate::scheduler::allocate;
    use crate::workload::{arrival_seed, simulate_chaos, ChaosConfig, FaultPlan, FaultSpec};

    let cfg = args.config()?;
    let (registry, alloc, spec) = loadgen_spec(args)?;
    let fspec = FaultSpec {
        horizon_s: args.f64_flag("horizon-s", 1.0)?,
        kills: args.usize_flag("kills", 1)?,
        stragglers: args.usize_flag("stragglers", 1)?,
        overloads: args.usize_flag("overloads", 1)?,
        crashes: args.usize_flag("crashes", 0)?,
    };
    anyhow::ensure!(fspec.horizon_s > 0.0, "--horizon-s must be positive");
    let drain_ms = args.f64_flag("drain-ms", 2.0)?;
    anyhow::ensure!(drain_ms >= 0.0, "--drain-ms must be non-negative");
    let deadline_s = match args.flags.get("deadline-ms") {
        None => None,
        Some(v) => {
            let deadline_ms: f64 = v.parse().with_context(|| format!("bad --deadline-ms {v:?}"))?;
            anyhow::ensure!(
                deadline_ms.is_finite() && deadline_ms > 0.0,
                "--deadline-ms must be positive and finite (got {deadline_ms})"
            );
            Some(deadline_ms / 1e3)
        }
    };
    let ccfg = ChaosConfig {
        queue_capacity: args.usize_flag("queue-capacity", 64)?.max(1),
        drain_s: drain_ms / 1e3,
        hedge: !args.bool_flag("no-hedge"),
        deadline_s,
    };
    // the reliability columns (expired / recoveries) appear only when a
    // §17 knob is in play, so legacy chaos CSVs stay byte-identical
    let reliability = fspec.crashes > 0 || ccfg.deadline_s.is_some();

    let plan = allocate(&registry, &cfg, &alloc)?;
    let mut headers = vec![
        "model", "arrivals", "replicas", "events", "submitted", "admitted", "shed",
        "completed",
    ];
    if reliability {
        headers.extend(["expired", "recoveries"]);
    }
    headers.extend([
        "replayed", "hedged", "kills", "p50_ms", "p99_ms", "makespan_ms", "status",
    ]);
    let mut t = Table::new(
        format!(
            "Chaos sim — seed {} | horizon {:.2}s | {} kill(s) {} straggler(s) \
             {} overload spike(s) | hedge {}{}",
            spec.seed,
            fspec.horizon_s,
            fspec.kills,
            fspec.stragglers,
            fspec.overloads,
            if ccfg.hedge { "on" } else { "off" },
            if reliability {
                format!(
                    " | {} crash(es), deadline {}",
                    fspec.crashes,
                    match ccfg.deadline_s {
                        Some(d) => format!("{:.1} ms", d * 1e3),
                        None => "off".to_string(),
                    },
                )
            } else {
                String::new()
            },
        ),
        &headers,
    );
    for load in &spec.loads {
        anyhow::ensure!(
            load.arrivals.offered_rate_hz().is_some(),
            "repro chaos is open-loop: closed:... arrivals are not supported"
        );
        let Some(a) = plan.assignment(&load.model) else {
            let status = if plan.rejected.iter().any(|r| r.name == load.model) {
                "rejected"
            } else {
                "queued"
            };
            let mut row = vec![load.model.clone(), load.arrivals.label()];
            row.extend(vec!["-".to_string(); if reliability { 14 } else { 12 }]);
            row.push(status.into());
            t.row(row);
            continue;
        };
        let tenant = registry.get(&load.model)?;
        let dep = crate::serving::deployment_sim(tenant, a, &cfg);
        // one pool-wide fault seed; per-tenant arrival seeds, like loadgen
        let fplan = FaultPlan::generate(spec.seed, &fspec, alloc.total_tpus, a.replicas);
        let run = simulate_chaos(
            &dep,
            &load.arrivals,
            load.requests,
            arrival_seed(spec.seed, &load.model),
            &fplan,
            &ccfg,
        );
        anyhow::ensure!(
            run.submitted == run.admitted + run.shed
                && run.admitted == run.completed + run.expired
                && run.submitted == run.completed + run.shed + run.expired,
            "{}: chaos accounting broke: {run:?}",
            load.model
        );
        let mut events = format!(
            "k{}/s{}/o{}",
            fplan.count("kill"),
            fplan.count("straggler"),
            fplan.count("overload")
        );
        if fspec.crashes > 0 {
            events.push_str(&format!("/c{}", fplan.count("crash")));
        }
        let mut row = vec![
            load.model.clone(),
            load.arrivals.label(),
            a.replicas.to_string(),
            events,
            run.submitted.to_string(),
            run.admitted.to_string(),
            run.shed.to_string(),
            run.completed.to_string(),
        ];
        if reliability {
            row.push(run.expired.to_string());
            row.push(run.recoveries.to_string());
        }
        row.extend([
            run.replayed.to_string(),
            run.hedged.to_string(),
            run.kills.to_string(),
            ms(run.p50_s()),
            ms(run.p99_s()),
            ms(run.makespan_s),
            "admitted".into(),
        ]);
        t.row(row);
    }
    let mut out = emit(t, args.csv());
    if !args.csv() {
        out.push_str(if reliability {
            "chaos sim: same --seed => bit-identical table | \
             submitted == completed + shed + expired, nothing is silent\n"
        } else {
            "chaos sim: same --seed => bit-identical table | \
             shed is accounted, admitted work always completes\n"
        });
    }
    if args.bool_flag("live") {
        out.push_str(&chaos_live(args, &cfg)?);
    }
    Ok(out)
}

/// The `--live` half of `repro chaos`: phased fault drills against a real
/// pool.  Counters in the narration vary with thread timing (hedge and
/// shed counts are load-dependent); the *verdicts* do not — bit-exact
/// responses, exact admission accounting, and drain-replay on kill are
/// hard failures.
fn chaos_live(args: &Args, cfg: &SystemConfig) -> Result<String> {
    use crate::coordinator::HedgeConfig;
    use crate::obs::{metric_line_from, MetricSource, TraceFile, Tracer};
    use crate::scheduler::{Admission, BackendKind, DeployOptions, ServingPool};
    use crate::util::json::Json;
    use crate::workload::faults::priority_tier;
    use std::sync::Arc;
    use std::time::Duration;

    // one seeded wave: submit, drain, verify every byte against the
    // serial reference
    fn wave(pool: &ServingPool, name: &str, n: usize, seed: u64) -> Result<()> {
        let client = pool.client(name)?;
        let reqs = client.synth_requests(n, seed);
        let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            pool.submit(name, r)?;
        }
        for _ in 0..n {
            let r = client.done.recv().context("completion stream closed early")?;
            anyhow::ensure!(
                r.data == expected[r.id as usize],
                "byte drift on request {}",
                r.id
            );
        }
        Ok(())
    }

    // hedge knobs are validated here, at CLI parse time, with the same
    // pinned messages HedgeConfig::validate pins at construction — a bad
    // flag fails fast instead of mid-drill
    let hedge = HedgeConfig {
        p99_factor: args.f64_flag("hedge-p99-factor", 2.0)?,
        min_samples: args.u64_flag("hedge-min-samples", 4)?,
    };
    hedge.validate()?;

    let (registry, alloc, spec) = loadgen_spec(args)?;
    let requests = args.usize_flag("live-requests", 40)?.max(1);
    let queue_capacity = args.usize_flag("live-queue-capacity", 8)?.max(2);
    let tracer: Option<Arc<Tracer>> =
        args.flags.contains_key("trace-out").then(|| Arc::new(Tracer::new()));
    let pool = ServingPool::deploy(
        registry,
        cfg.clone(),
        alloc.clone(),
        BackendKind::Synthetic,
        DeployOptions {
            policy: spec.policy,
            queue_capacity,
            tracer: tracer.clone(),
            hedge: Some(hedge),
            ..Default::default()
        },
    )?;
    let mut out = String::from("\nchaos live (synthetic backend):\n");
    let mut failures: Vec<String> = Vec::new();

    // ---- phase 1: baseline round trip, every tenant
    for name in pool.names() {
        match wave(&pool, &name, requests, spec.seed) {
            Ok(()) => out.push_str(&format!(
                "  baseline {name}: {requests} request(s) bit-exact\n"
            )),
            Err(e) => failures.push(format!("baseline/{name}: {e}")),
        }
    }

    // ---- phase 2: injected straggler -> hedged dispatch
    let replicated = pool
        .plan()
        .assignments
        .iter()
        .find(|a| a.replicas > 1)
        .map(|a| (a.name.clone(), a.replicas));
    match &replicated {
        Some((name, replicas)) => {
            let drill = (|| -> Result<u64> {
                pool.inject_straggler(name, 0, Duration::from_millis(15))?;
                for w in 0..2u64 {
                    wave(&pool, name, requests, spec.seed.wrapping_add(1 + w))?;
                }
                pool.clear_straggler(name, 0)?;
                // responses ship before the worker books the hedge delta
                std::thread::sleep(Duration::from_millis(50));
                let snap = pool
                    .tenant_metrics(name)
                    .ok_or_else(|| anyhow::anyhow!("no metrics for {name}"))?
                    .snapshot();
                anyhow::ensure!(
                    snap.hedges >= 1,
                    "slowed replica 0/{replicas} never triggered a hedge"
                );
                Ok(snap.hedges)
            })();
            match drill {
                Ok(h) => out.push_str(&format!(
                    "  straggler {name}: 15 ms on replica 0/{replicas} -> \
                     {h} hedged dispatch(es), responses bit-exact\n"
                )),
                Err(e) => failures.push(format!("straggler/{name}: {e}")),
            }
        }
        None => out.push_str(
            "  straggler: no replicated tenant in this plan; hedge drill skipped\n",
        ),
    }

    // ---- phase 3: tiered overload burst -> shed with exact accounting
    if let Some(name) = pool.names().first().cloned() {
        let drill = (|| -> Result<(usize, usize)> {
            // slow every replica down so the burst actually backs up
            if let Some((rep_name, replicas)) = &replicated {
                if rep_name == &name {
                    for r in 0..*replicas {
                        pool.inject_straggler(&name, r, Duration::from_millis(10))?;
                    }
                }
            }
            let client = pool.client(&name)?;
            let burst = 3 * queue_capacity;
            let reqs = client.synth_requests(burst, spec.seed ^ 0xB00);
            let expected: Vec<Vec<i8>> =
                reqs.iter().map(|r| client.reference(&r.data)).collect();
            let mut accepted = std::collections::BTreeSet::new();
            let mut shed = 0usize;
            for (i, r) in reqs.into_iter().enumerate() {
                let tier = priority_tier(i);
                match pool.submit_with_priority(&name, r, tier)? {
                    Admission::Accepted => {
                        accepted.insert(i as u64);
                    }
                    Admission::Shed => {
                        anyhow::ensure!(tier != 0, "tier 0 must never be shed");
                        shed += 1;
                    }
                    Admission::Expired => {
                        anyhow::bail!("no deadlines configured, yet a request expired")
                    }
                }
            }
            anyhow::ensure!(accepted.len() + shed == burst, "admission accounting broke");
            for _ in 0..accepted.len() {
                let r = client.done.recv().context("completion stream closed early")?;
                anyhow::ensure!(accepted.contains(&r.id), "shed request {} completed", r.id);
                anyhow::ensure!(
                    r.data == expected[r.id as usize],
                    "byte drift on request {}",
                    r.id
                );
            }
            if let Some((rep_name, replicas)) = &replicated {
                if rep_name == &name {
                    for r in 0..*replicas {
                        pool.clear_straggler(&name, r)?;
                    }
                }
            }
            Ok((accepted.len(), shed))
        })();
        match drill {
            Ok((acc, shed)) => out.push_str(&format!(
                "  overload {name}: {} offered -> {acc} accepted, {shed} shed \
                 (tier 0 untouched); accepted responses bit-exact\n",
                acc + shed,
            )),
            Err(e) => failures.push(format!("overload/{name}: {e}")),
        }
    }

    // ---- phase 4: mid-run device kill -> re-plan, drain replay, recovery
    let victim = pool.plan().assignments.first().and_then(|a| a.devices.first().copied());
    match victim {
        Some(device) if alloc.total_tpus >= 2 => {
            let drill = (|| -> Result<String> {
                // put every tenant's traffic in flight, then yank the device
                let mut pending = Vec::new();
                for name in pool.names() {
                    let client = pool.client(&name)?;
                    let reqs = client.synth_requests(requests, spec.seed ^ 0xD1E);
                    let expected: Vec<Vec<i8>> =
                        reqs.iter().map(|r| client.reference(&r.data)).collect();
                    for r in reqs {
                        pool.submit(&name, r)?;
                    }
                    pending.push((name, client, expected));
                }
                let report = pool.kill_device(device)?;
                anyhow::ensure!(
                    report.drained >= 1,
                    "killing an assigned device must drain at least one deployment"
                );
                for (name, client, expected) in &pending {
                    for _ in 0..requests {
                        let r =
                            client.done.recv().context("completion stream closed early")?;
                        anyhow::ensure!(
                            r.data == expected[r.id as usize],
                            "{name}: byte drift on drained request {}",
                            r.id
                        );
                    }
                }
                anyhow::ensure!(
                    pool.dead_devices().contains(&device),
                    "killed device must stay quarantined"
                );
                // the survivors keep serving bit-exact after the re-plan
                for name in &report.admitted {
                    wave(&pool, name, requests, spec.seed ^ 0xA11)?;
                }
                Ok(format!(
                    "  kill: device {device} died mid-run -> drained {} deployment(s), \
                     re-plan admitted {} queued {}; every in-flight + recovery \
                     response bit-exact\n",
                    report.drained,
                    report.admitted.len(),
                    report.queued,
                ))
            })();
            match drill {
                Ok(line) => out.push_str(&line),
                Err(e) => failures.push(format!("kill/device{device}: {e}")),
            }
        }
        _ => out.push_str("  kill: pool too small for a device-kill drill; skipped\n"),
    }

    // ---- phase 5: controller crash -> journal warm-restart (§17).
    // A second, journaled pool serves a wave, "crashes" (shutdown leaves
    // the WAL's register events in place), and recover() must rebuild the
    // exact pre-crash plan and keep serving bit-exact.
    {
        let drill = (|| -> Result<String> {
            let (reg2, alloc2, spec2) = loadgen_spec(args)?;
            let jpath = std::env::temp_dir()
                .join(format!("repro-chaos-recover-{}.journal", std::process::id()));
            let _ = std::fs::remove_file(&jpath);
            let opts = DeployOptions {
                policy: spec2.policy,
                queue_capacity,
                ..Default::default()
            };
            let crashed = ServingPool::deploy(
                reg2,
                cfg.clone(),
                alloc2.clone(),
                BackendKind::Synthetic,
                opts.clone().with_journal(&jpath),
            )?;
            for name in crashed.names() {
                wave(&crashed, &name, requests, spec2.seed ^ 0x0C7)?;
            }
            let before = format!("{:?}", crashed.plan().assignments);
            let tenants = crashed.names().len();
            crashed.shutdown(); // the "crash": nothing is deregistered
            let recovered = ServingPool::recover(
                cfg.clone(),
                alloc2,
                BackendKind::Synthetic,
                opts,
                &jpath,
            )?;
            anyhow::ensure!(
                format!("{:?}", recovered.plan().assignments) == before,
                "recovered plan diverged from the pre-crash plan"
            );
            for name in recovered.names() {
                wave(&recovered, &name, requests, spec2.seed ^ 0x0C8)?;
            }
            recovered.shutdown();
            let _ = std::fs::remove_file(&jpath);
            Ok(format!(
                "  recover: controller crashed with {tenants} journaled tenant(s) -> \
                 warm-restart rebuilt the exact plan; post-recovery responses \
                 bit-exact\n"
            ))
        })();
        match drill {
            Ok(line) => out.push_str(&line),
            Err(e) => failures.push(format!("recover: {e}")),
        }
    }

    // ---- exports (written even on failure: the trace is the diagnosis)
    let mut metrics_out: Vec<(String, String, Json)> = Vec::new();
    for name in pool.names() {
        if let Some(m) = pool.tenant_metrics(&name) {
            metrics_out.push((m.metric_kind().to_string(), name.clone(), m.metric_json()));
        }
    }
    let sched = &*pool.metrics;
    metrics_out.push((sched.metric_kind().to_string(), "pool".to_string(), sched.metric_json()));
    if let Some(path) = args.flags.get("metrics-out") {
        let jsonl: String = metrics_out
            .iter()
            .map(|(k, n, j)| metric_line_from(k, n, j.clone()))
            .collect();
        std::fs::write(path, jsonl)
            .with_context(|| format!("writing --metrics-out {path:?}"))?;
    }
    if let (Some(path), Some(tr)) = (args.flags.get("trace-out"), &tracer) {
        std::fs::write(path, TraceFile::from_tracer("repro chaos", tr).to_json())
            .with_context(|| format!("writing --trace-out {path:?}"))?;
    }
    pool.shutdown();

    if failures.is_empty() {
        out.push_str(
            "chaos live: PASS — shed requests accounted, admitted work verified \
             bit-exact through every fault\n",
        );
        Ok(out)
    } else {
        print!("{out}");
        anyhow::bail!("chaos live drills failed: {}", failures.join("; "))
    }
}

/// `repro recover`: the crash-recovery drill (DESIGN.md §17).
///
/// `--write` is the drill's first half: start a fresh journal at
/// `--journal`, deploy a *journaled* pool from the usual pool/loadgen
/// flags, serve a seeded wave bit-exact, and exit without deregistering
/// anything — exactly what a crashed controller leaves behind.  A later
/// plain invocation replays the WAL, rebuilds the registry from the
/// journal (not from `--models`), warm-restarts a live pool via
/// `ServingPool::recover` (plan-fingerprint check + generation fencing),
/// serves a verification wave, and renders the deterministic loadgen
/// table for the recovered tenants.  That table is a pure function of
/// (journal, flags): its `--csv` form is byte-identical to what an
/// uninterrupted `repro loadgen --csv` prints with the same flags — the
/// golden contract `make smoke-recover` diffs.  The live warm-restart
/// runs even under `--csv` (only the table is printed); `--no-live`
/// skips it.
pub fn recover_cmd(args: &Args) -> Result<String> {
    use crate::coordinator::batcher::BatchPolicy;
    use crate::scheduler::{replay_journal, BackendKind, DeployOptions, Journal, ServingPool};
    use crate::workload::{Arrivals, TenantLoad};
    use std::path::PathBuf;

    // one seeded wave: submit, drain, verify every byte against the
    // serial reference
    fn wave(pool: &ServingPool, name: &str, n: usize, seed: u64) -> Result<()> {
        let client = pool.client(name)?;
        let reqs = client.synth_requests(n, seed);
        let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            pool.submit(name, r)?;
        }
        for _ in 0..n {
            let r = client.done.recv().context("completion stream closed early")?;
            anyhow::ensure!(
                r.data == expected[r.id as usize],
                "byte drift on request {}",
                r.id
            );
        }
        Ok(())
    }

    let cfg = args.config()?;
    let path = PathBuf::from(
        args.flags
            .get("journal")
            .ok_or_else(|| anyhow::anyhow!("repro recover needs --journal FILE"))?,
    );

    if args.bool_flag("write") {
        // drill half 1: a journaled pool that "crashes" after serving
        let _ = std::fs::remove_file(&path); // --write starts a fresh drill
        let (registry, alloc, spec) = loadgen_spec(args)?;
        let pool = ServingPool::deploy(
            registry,
            cfg,
            alloc,
            BackendKind::Synthetic,
            DeployOptions { policy: spec.policy, ..Default::default() }.with_journal(&path),
        )?;
        let names = pool.names();
        for name in &names {
            wave(&pool, name, spec.loads[0].requests.min(20), spec.seed)?;
        }
        // shutdown() deregisters nothing in the WAL: the file now holds
        // exactly what a controller crash would leave behind
        pool.shutdown();
        return Ok(format!(
            "journal written: {} tenant(s) registered, plan fingerprint \
             snapshotted at {}\ncrash simulated (nothing deregistered); run \
             `repro recover --journal {}` to warm-restart\n",
            names.len(),
            path.display(),
            path.display(),
        ));
    }

    // drill half 2: replay the WAL and warm-restart
    let log = Journal::load(&path)?;
    anyhow::ensure!(
        log.generation > 0,
        "no journal to recover from at {}",
        path.display()
    );
    let (registry, dead) = replay_journal(&log)?;

    // sizing/load flags must match the crashed deployment's invocation;
    // the tenancy itself comes from the journal, not from --models
    let (_, alloc) = pool_spec(args, "fc_small")?;
    let seed = args.u64_flag("seed", 7)?;
    let requests = args.usize_flag("requests", 200)?;
    anyhow::ensure!(requests >= 1, "--requests must be at least 1");
    let arrivals = Arrivals::parse(&args.str_flag("arrivals", "poisson:400"))?;
    let max_wait_ms = args.f64_flag("max-wait-ms", 2.0)?;
    anyhow::ensure!(max_wait_ms >= 0.0, "--max-wait-ms must be non-negative");
    let policy = BatchPolicy {
        max_batch: args.usize_flag("max-batch", 8)?,
        max_wait: std::time::Duration::from_secs_f64(max_wait_ms / 1e3),
    };
    // loads in --models order when given (byte-identity with the
    // uninterrupted loadgen run), sorted registry order otherwise
    let order: Vec<String> = match args.flags.get("models") {
        Some(models) => {
            let names: Vec<String> = models
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            for n in &names {
                anyhow::ensure!(
                    registry.get(n).is_ok(),
                    "--models lists {n:?}, which the journal never registered"
                );
            }
            anyhow::ensure!(
                names.len() == registry.len(),
                "--models must list every journaled tenant (journal has {})",
                registry.len()
            );
            names
        }
        None => registry.iter().map(|t| t.name.clone()).collect(),
    };
    let loads: Vec<TenantLoad> = order
        .iter()
        .map(|name| TenantLoad {
            model: name.clone(),
            arrivals: arrivals.clone(),
            requests,
        })
        .collect();
    let spec = LoadgenSpec { loads, seed, policy };
    let (table, plan) = loadgen_table(&registry, &cfg, &alloc, &spec)?;

    // warm-restart the live pool from the journal: recover() re-plans,
    // verifies the snapshot fingerprint, and fences the generation
    let live = if args.bool_flag("no-live") {
        None
    } else {
        let pool = ServingPool::recover(
            cfg.clone(),
            alloc.clone(),
            BackendKind::Synthetic,
            DeployOptions { policy: spec.policy, ..Default::default() },
            &path,
        )?;
        for name in pool.names() {
            wave(&pool, &name, requests.min(20), seed ^ 0x9E)?;
        }
        let n = pool.names().len();
        pool.shutdown();
        Some(n)
    };

    let mut out = emit(table, args.csv());
    if !args.csv() {
        out.push_str(&format!(
            "recover: journal generation {} replayed -> {} tenant(s) admitted, \
             {} dead device(s) | plan fingerprint {}",
            log.generation,
            plan.assignments.len(),
            dead.len(),
            match log.last_fingerprint() {
                Some(f) => format!("{f:016x}"),
                None => "absent".to_string(),
            },
        ));
        out.push_str(&match live {
            Some(n) => {
                format!(" | live warm-restart served {n} tenant(s) bit-exact\n")
            }
            None => " | live warm-restart skipped (--no-live)\n".to_string(),
        });
    }
    Ok(out)
}

/// Parse the calibration-scenario flags — `--windows`,
/// `--window-requests`, `--drift MODEL[,MODEL..]`, `--drift-onset`,
/// `--drift-threshold`, `--sustain-windows`, `--cooldown-windows`,
/// `--min-samples` — on top of a default scenario.  Shared by
/// `repro calibrate` and `repro loadgen --calibrate`, so both harnesses
/// accept the same grammar.
pub fn calibrate_scenario(
    args: &Args,
    registry: &crate::scheduler::ModelRegistry,
    seed: u64,
) -> Result<crate::scheduler::CalibrateScenario> {
    use crate::scheduler::CalibrateScenario;

    let mut sc = CalibrateScenario::new(seed);
    sc.windows = args.usize_flag("windows", sc.windows)?;
    anyhow::ensure!(sc.windows >= 1, "--windows must be at least 1");
    sc.requests_per_window = args.usize_flag("window-requests", sc.requests_per_window)?;
    anyhow::ensure!(sc.requests_per_window >= 1, "--window-requests must be at least 1");
    sc.drift_onset_window = args.usize_flag("drift-onset", sc.drift_onset_window)?;
    if let Some(spec) = args.flags.get("drift") {
        for name in spec.split(',').map(str::trim).filter(|n| !n.is_empty()) {
            anyhow::ensure!(
                registry.get(name).is_ok(),
                "--drift names unregistered model {name:?}"
            );
            sc.drifted.push(name.to_string());
        }
        anyhow::ensure!(!sc.drifted.is_empty(), "--drift must name at least one model");
    }
    sc.calibrate.drift_threshold =
        args.f64_flag("drift-threshold", sc.calibrate.drift_threshold)?;
    sc.calibrate.sustain_windows =
        args.usize_flag("sustain-windows", sc.calibrate.sustain_windows as usize)? as u32;
    sc.calibrate.cooldown_windows =
        args.usize_flag("cooldown-windows", sc.calibrate.cooldown_windows as usize)? as u32;
    sc.calibrate.min_samples = args.u64_flag("min-samples", sc.calibrate.min_samples)?;
    sc.calibrate.validate()?;
    Ok(sc)
}

/// Render a calibration run as the `repro calibrate` report table: one
/// row per (window, tenant) with predicted vs observed p99, the measured
/// drift, and the action the detector took.
pub fn calibration_table(run: &crate::scheduler::CalibrationRun) -> Table {
    let windows = run.rows.last().map(|r| r.window + 1).unwrap_or(0);
    let mut t = Table::new(
        format!(
            "Online calibration — {windows} window(s), {} re-plan(s)",
            run.ledger.len()
        ),
        &[
            "window", "model", "samples", "predicted_p99_ms", "observed_p99_ms",
            "drift_pct", "action",
        ],
    );
    for r in &run.rows {
        t.row(vec![
            r.window.to_string(),
            r.model.clone(),
            r.samples.to_string(),
            format!("{:.3}", r.predicted_p99_s * 1e3),
            format!("{:.3}", r.observed_p99_s * 1e3),
            format!("{:+.1}", r.drift * 100.0),
            r.action.clone(),
        ]);
    }
    t
}

/// The human-mode tail of the calibration report: the re-plan ledger
/// (every drift-triggered recalibration, in firing order) plus the final
/// cost model for tenants whose scale moved off 1.0.
pub fn calibration_summary(run: &crate::scheduler::CalibrationRun) -> String {
    let mut s = String::new();
    if run.ledger.is_empty() {
        s.push_str("\nre-plan ledger: empty (no sustained drift)\n");
    } else {
        s.push_str(&format!("\nre-plan ledger ({} entries):\n", run.ledger.len()));
        for r in &run.ledger {
            s.push_str(&format!(
                "  window {:>2}  {:12} drift {:+.1}% -> cost_scale x{:.2} (re-plan)\n",
                r.window,
                r.tenant,
                r.drift * 100.0,
                r.scale,
            ));
        }
        s.push_str("final cost model:\n");
        for (name, scale) in &run.final_scales {
            if *scale != 1.0 {
                let p99 = run
                    .final_plan
                    .assignment(name)
                    .map(|a| format!("{:.3} ms", a.effective_p99_s * 1e3))
                    .unwrap_or_else(|| "-".to_string());
                s.push_str(&format!(
                    "  {name:12} x{scale:.2} (re-planned predicted p99 {p99})\n"
                ));
            }
        }
    }
    s
}

/// `repro calibrate`: close the profiling loop, deterministically — drive
/// the seeded multi-window calibration simulation (DESIGN.md §16) over the
/// scheduled pool, with the hidden true cost of `--drift` tenants jumping
/// by a seeded factor at `--drift-onset`.  The calibrator measures
/// predicted-vs-observed p99 per window, rewrites drifting tenants' cost
/// models, and re-plans; the report shows every window's drift and the
/// re-plan ledger.  Pure function of the seed: `--csv` output is
/// byte-identical across runs (`make smoke-calibrate` diffs it).
pub fn calibrate(args: &Args) -> Result<String> {
    use crate::scheduler::{calibration_csv, simulate_calibration};

    let cfg = args.config()?;
    let (registry, alloc) = pool_spec(args, "fc_big,fc_small")?;
    let scenario = calibrate_scenario(args, &registry, args.u64_flag("seed", 7)?)?;
    let run = simulate_calibration(&registry, &cfg, &alloc, &scenario)?;
    if args.csv() {
        return Ok(calibration_csv(&run));
    }
    let mut out = calibration_table(&run).render();
    out.push_str(&calibration_summary(&run));
    Ok(out)
}

/// The `--calibrate` rider on `repro loadgen`: when the flag is present,
/// run the calibration simulation over the *same* registry/plan inputs
/// and seed as the loadgen tables and return the report to append (CSV in
/// `--csv` mode, rendered table + ledger otherwise).  Returns `None`
/// without the flag, keeping default loadgen output byte-identical.
pub fn loadgen_calibration(
    args: &Args,
    registry: &crate::scheduler::ModelRegistry,
    cfg: &SystemConfig,
    alloc: &crate::scheduler::AllocatorConfig,
    spec: &LoadgenSpec,
) -> Result<Option<String>> {
    use crate::scheduler::{calibration_csv, simulate_calibration};

    if !args.bool_flag("calibrate") {
        return Ok(None);
    }
    let mut scenario = calibrate_scenario(args, registry, spec.seed)?;
    scenario.policy = spec.policy;
    if let Some(l) = spec.loads.first() {
        scenario.arrivals = l.arrivals.clone();
    }
    let run = simulate_calibration(registry, cfg, alloc, &scenario)?;
    Ok(Some(if args.csv() {
        calibration_csv(&run)
    } else {
        let mut s = String::from("\n");
        s.push_str(&calibration_table(&run).render());
        s.push_str(&calibration_summary(&run));
        s
    }))
}

/// `repro trace`: load a `--trace-out` file and render it as an ASCII
/// Gantt (one row per track; Perfetto-grade inspection stays available by
/// opening the same file in <https://ui.perfetto.dev>).
pub fn trace_cmd(args: &Args) -> Result<String> {
    let path = args
        .flags
        .get("in")
        .ok_or_else(|| anyhow::anyhow!("repro trace needs --in FILE (a --trace-out file)"))?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace file {path:?}"))?;
    let file = crate::obs::TraceFile::parse(&text)?;
    let width = args.usize_flag("width", 100)?.max(10);
    Ok(crate::trace::trace_ascii(&file, width))
}

/// Replication (data parallelism) vs profiled segmentation (§V-C remark).
fn ablation_replicate(kind: Kind, cfg: &SystemConfig, batch: usize) -> String {
    let mut t = Table::new(
        format!("Ablation ({}) — profiled segmentation vs k-replica data parallelism", kind.label()),
        &["x", "seg_ms", "rep_ms", "seg_advantage"],
    );
    for m in kind.models().iter().step_by(4) {
        let r = crate::ablation::replication_vs_segmentation(m, 4, cfg, batch);
        t.row(vec![
            kind.x_of(m).to_string(),
            ms(r.seg_per_item_s),
            ms(r.rep_per_item_s),
            speedup(r.seg_advantage),
        ]);
    }
    t.render()
}

/// Hybrid CPU-TPU pipeline (§VI future work).
fn ablation_hybrid(cfg: &SystemConfig, batch: usize) -> String {
    let mut t = Table::new(
        "Ablation (FC) — hybrid CPU-TPU pipeline vs spilled single TPU",
        &["x", "single_tpu_ms", "hybrid_ms", "hybrid_speedup"],
    );
    for m in Kind::Fc.models().iter().step_by(2) {
        if let Some(h) = crate::ablation::hybrid_cpu_tpu_per_item_s(m, cfg, batch) {
            let t1 = crate::pipeline::single_tpu_latency_s(m, cfg);
            t.row(vec![
                Kind::Fc.x_of(m).to_string(),
                ms(t1),
                ms(h),
                speedup(t1 / h),
            ]);
        }
    }
    t.render()
}

/// Energy ablation (§VI future work).
fn ablation_energy(kind: Kind, cfg: &SystemConfig, batch: usize) -> String {
    let mut t = Table::new(
        format!("Ablation ({}) — energy per inference (mJ)", kind.label()),
        &["x", "single_tpu_mJ", "pipeline4_mJ", "cpu_mJ"],
    );
    for m in kind.models().iter().step_by(8) {
        let e = crate::ablation::energy(m, 4, cfg, batch);
        t.row(vec![
            kind.x_of(m).to_string(),
            format!("{:.2}", e.single_tpu_j * 1e3),
            format!("{:.2}", e.pipeline_j * 1e3),
            format!("{:.2}", e.cpu_j * 1e3),
        ]);
    }
    t.render()
}

pub const USAGE: &str = "\
repro — reproduction harness for 'Improving inference time in multi-TPU
systems with profiled model segmentation' (PDP 2023)

USAGE: repro <command> [--kind fc|conv] [--batch N] [--csv]
             [--config cfg.json] [--strategy uniform|memory|profiled|threshold]

paper experiments (cost-model simulator):
  fig2a fig2b fig2c      single-TPU sweeps (time / GOPS / vs CPU)
  table1 table2          memory+latency around each host-memory step
  fig4                   single-input latency on 1..4 TPUs (default split)
  fig-batch              batched speedups, default split (§V-B figure)
  table3 table3b table4  per-device memory, default splits
  table5 table6          per-device memory, profiled splits (§V-C)
  fig5 fig6              profiled batched times + headline speedups
  headline               the abstract's 46x / 6x numbers
  all                    everything above

ablations (beyond the paper; §V-C/§VI discussion made quantitative):
  ablation-replicate     profiled segmentation vs data-parallel replicas
  ablation-hybrid        hybrid CPU-TPU pipeline for spilled FC models
  ablation-energy        J/inference: 1 TPU vs 4-TPU pipeline vs CPU

multi-tenant pool scheduler (cost-model simulation; no artifacts needed):
  schedule --models fc_big,conv_a,conv_b --tpus 4
           [--weights 2,1,1] [--slo-ms 20,-,50] [--allow-spill]
           [--max-tpus-per-model 4] [--no-replicas]
           [--allow-sharing] [--switch-cost-us US] [--max-residents 2]
           [--quantum-us US] [--cache-budget-bytes N] [--prefetch]
        memory-aware admission + per-model (tpu_count, strategy, p99)
        chosen by the pool allocator; models: fc_small fc_big fc_huge
        conv_a conv_b conv_big pyramid, or fc_n<width> / conv_f<filters>.
        --allow-sharing folds time-multiplexed per-device slices into the
        branch-and-bound itself: a tenant's grant is exclusive or a
        1/2..1/max-residents slice of each device it runs on, tenants of
        different pipeline depths may overlap on a device subset (the
        devices column shows the concrete ids), and every shared
        candidate's p99 prices in the context-switch cost (segment
        parameter re-load from host memory, derived from the cost
        model's off-chip bandwidth — override with --switch-cost-us).
        A shared grant breaching the tenant's own SLO is never made.
        --quantum-us sets the scheduling-quantum length: longer quanta
        swap less often under overload (throughput) at a priced-in
        (1-slice)*quantum worst-case wait (latency); 0 swaps per flush.
        --cache-budget-bytes N gives every device a host-staging cache
        of N bytes for segment parameters: co-residents whose combined
        footprint fits it swap warm (near-zero re-load), partially
        fitting groups pay only the unpinned fraction, and the packing
        pass prefers device groups that fit together (the cache_warm
        column shows each grant's warm fraction).  --prefetch overlaps
        the residual re-load with the tail of the previous quantum.
        0 (the default) disables the cache model byte-for-byte.
        Tenants with --slo-ms also print their derived batch policy
        (max_wait shrinks under tight SLOs)

serving (real numerics; PJRT needs `make artifacts`):
  serve --model fc_n512 --tpus 4 [--strategy profiled] [--batch 50]
        [--replicas N] [--artifacts DIR]
        single-model pipelined serving; --replicas N runs N data-parallel
        pipeline copies behind the round-robin ReplicaRouter
  serve-pool --models fc_big,fc_small --tpus 4 [--batch 50]
        [--trace-out FILE] [--metrics-out FILE]
        deploy the scheduled pool and serve synthetic traffic for every
        admitted model concurrently (native deterministic backend);
        accepts the same pool flags as `schedule` (--weights, --slo-ms,
        --allow-spill, --max-tpus-per-model, --no-replicas).
        --trace-out saves the live span trace (Chrome/Perfetto JSON);
        --metrics-out saves end-of-run metric snapshots as JSONL
  gantt --kind fc --x 2100 --tpus 3 [--batch 8] [--strategy profiled]
        ASCII pipeline schedule trace

open-loop load generation (seeded, bit-reproducible):
  loadgen --models fc_small,conv_a --tpus 4 --seed 7 --requests 200
          [--arrivals poisson:400]       one spec, or one per model:
              poisson:RATE | bursty:RATE:ON_S:OFF_S | closed:CONC:THINK_S
          [--max-batch 8] [--max-wait-ms 2]   base flush policy (tenants
              with --slo-ms derive a tighter per-tenant max_wait)
          [--join MODEL@T_S] [--leave MODEL@T_S]  register/deregister the
              model T_S seconds into the live run (online re-plan + drain)
          [--allow-sharing]  time-multiplexed co-residency (see schedule);
              shared tenants report deterministic swap counts + overhead
          [--quantum-us US]  scheduling-quantum length: flushes inside the
              quantum keep parameters resident (fewer swaps, more
              throughput, later p99 — the quantum_us column echoes it)
          [--cache-budget-bytes N] [--prefetch]  per-device parameter
              cache (see schedule): cache-enabled runs add deterministic
              cache_hits / cache_misses / prefetches / hit_rate columns
              (hits + misses == swaps), a {model}/cache prefetch track in
              --trace-out, and cache counters in --metrics-out; budget 0
              reproduces the cache-less output byte-for-byte
          [--no-replicas]    plan without leftover-TPU replica grants
          [--no-live]  print only the deterministic table
          [--csv]      CSV table only (identical across runs of one seed)
          [--trace-out FILE]    save the deterministic sim span trace as
              Chrome/Perfetto trace JSON — byte-identical per seed, like
              the CSV (open in https://ui.perfetto.dev or `repro trace`)
          [--metrics-out FILE]  save per-tenant metric snapshots as JSONL
              (streaming-histogram percentiles; byte-identical per seed)
          [--calibrate]  append the deterministic calibration report
              (same grammar as `repro calibrate`, same seed as the run);
              without the flag, output is byte-identical to before
        prints the deterministic per-tenant table (offered rate, replica
        fan-out, grant kind, batch + flush-reason + swap counts,
        p50/p99/mean latency, throughput) from the seeded open-loop
        queueing simulation, then replays the same seeds against the live
        open-loop pool (per-tenant Batcher workers) with bit-exact
        response verification

zero-copy data plane (live smoke; `make smoke-dataplane` runs this):
  dataplane --models fc_small,conv_a --tpus 2 [--alloc-budget 0]
            [--batch 50] [--warmup 3] [--iters 5]
            [--open-warmup 40] [--open-requests 80]
            [--trace-out FILE] [--metrics-out FILE]
            accepts the pool flags of `schedule` (--allow-sharing, ...).
        serves live traffic through the closed-batch router and the
        open-loop pool, then FAILS unless steady-state arena allocations
        per request stay within --alloc-budget (default 0: a warm data
        plane recycles every activation slab).  Responses are verified
        bit-for-bit against the serial reference throughout.
        --trace-out enables the live span tracer (host-clock spans; the
        budget gate always runs with tracing off) and saves the trace;
        --metrics-out saves every end-of-run snapshot as JSONL

chaos & failure testing (DESIGN.md §14; `make smoke-chaos` runs this):
  chaos --models fc_small,conv_a --tpus 4 --seed 7 --requests 200
        [--arrivals poisson:400]   open-loop specs only (no closed:...)
        [--kills 1] [--stragglers 1] [--overloads 1] [--horizon-s 1]
            seeded fault schedule: device deaths (drain + re-plan replay),
            straggler windows (hedged dispatch), overload spikes
            (priority-tiered shedding)
        [--crashes 0]        controller crash/warm-restart outages in the
            sim (DESIGN.md §17): ingress sheds at the door while the
            control plane is down, replays survive; adds the expired +
            recoveries columns (and /cN in events).  0 keeps legacy CSVs
            byte-identical
        [--deadline-ms MS]   dispatch-start deadline in the sim: requests
            whose queueing delay exceeds MS expire before consuming any
            server time (submitted == completed + shed + expired)
        [--queue-capacity 64] [--drain-ms 2] [--no-hedge]
        [--csv]      CSV table only — byte-identical across runs of one
            seed (the golden artifact the smoke target diffs)
        [--live]     then drill the same fault kinds against a real
            ServingPool (synthetic backend): baseline round trip, injected
            replica straggler -> hedges, tiered overload burst -> shed
            with exact accounting, a mid-run kill_device -> drained
            in-flight work replays and verifies bit-exact, and a
            controller crash -> journal warm-restart rebuilding the exact
            plan.  FAILS if any admitted request is lost or corrupted;
            shed is never silent
        [--live-requests 40] [--live-queue-capacity 8]
        [--hedge-p99-factor 2] [--hedge-min-samples 4]   (--live) hedge
            knobs, validated at parse with the constructor's messages
        [--trace-out FILE]    (--live) save the live span trace, including
            the chaos/faults track with one span per device kill
        [--metrics-out FILE]  (--live) end-of-run snapshots as JSONL
            (hedges, shed, device_kills ride the metric schema)

crash recovery (DESIGN.md §17; `make smoke-recover` runs this):
  recover --journal FILE [pool/loadgen flags] [--csv] [--no-live]
        warm-restart a crashed pool from its recovery journal: replay
        the WAL (registry rebuilt from the journal, not --models),
        ServingPool::recover re-plans, verifies the snapshot plan
        fingerprint, fences the generation, serves a verification wave
        bit-exact (skipped by --no-live), and renders the deterministic
        loadgen table for the recovered tenants — with the same flags,
        byte-identical to an uninterrupted `repro loadgen --csv` run
  recover --journal FILE --write [pool/loadgen flags]
        the drill's first half: start a fresh journal, deploy a
        journaled pool, serve a wave, exit WITHOUT deregistering —
        leaving exactly what a controller crash leaves behind

online cost-model calibration (DESIGN.md §16; `make smoke-calibrate`):
  calibrate --models fc_big,fc_small --tpus 4 --seed 7
        [--windows 6] [--window-requests 120]   calibration windows and
            requests offered to every tenant per window
        [--drift MODEL[,MODEL..]] [--drift-onset 2]   from window
            --drift-onset on, the named tenants' hidden true cost jumps
            by a seeded factor (1.8x..2.5x) the profile does not know
        [--drift-threshold 0.5] [--sustain-windows 2]
        [--cooldown-windows 3] [--min-samples 20]   detector knobs: fire
            only after drift holds --sustain-windows windows, then hold
            --cooldown-windows (flap guard; hysteresis keeps a borderline
            tenant from resetting its streak)
        [--csv]      CSV report only — byte-identical across runs of one
            seed (the golden artifact `make smoke-calibrate` diffs)
        accepts the pool flags of `schedule` (--weights, --slo-ms,
        --allow-sharing, ...).  Simulates the closed profiling loop:
        per window, predicted-vs-observed p99 per tenant; on sustained
        drift the calibrator rewrites that tenant's cost model
        (cost_scale) and re-plans the pool.  The report shows every
        window's drift, the re-plan ledger, and the final cost model.
        The same loop runs live inside a ServingPool deployed with
        DeployOptions::with_calibration (calibrate_tick / ticker thread)

observability (DESIGN.md §13):
  trace --in FILE [--width 100]
        render a saved --trace-out file (Chrome/Perfetto trace JSON) as
        an ASCII Gantt: one row per track, digits keyed by span id, plus
        the span/track/drop totals
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parse_flags() {
        let a = Args::parse(&argv("fig2a --kind conv --batch 25 --csv")).unwrap();
        assert_eq!(a.command, "fig2a");
        assert_eq!(a.kind().unwrap(), Kind::Conv);
        assert_eq!(a.batch().unwrap(), 25);
        assert!(a.csv());
        let a = Args::parse(&argv("serve --model=fc_n256")).unwrap();
        assert_eq!(a.str_flag("model", ""), "fc_n256");
    }

    #[test]
    fn parse_rejects_stray_positional() {
        assert!(Args::parse(&argv("fig2a extra")).is_err());
    }

    #[test]
    fn fig2a_renders() {
        let out = fig2a(Kind::Fc, &SystemConfig::default(), false);
        assert!(out.contains("Fig 2a"));
        assert!(out.lines().count() > 60); // 64 sweep points + header
    }

    #[test]
    fn table1_has_step_pairs() {
        let out = table_steps(Kind::Fc, &SystemConfig::default(), false);
        // at least two steps -> at least 4 data rows
        assert!(out.lines().count() >= 6, "{out}");
        assert!(out.contains("Table I"));
    }

    #[test]
    fn csv_mode_is_parseable() {
        let out = fig2b(Kind::Fc, &SystemConfig::default(), true);
        let first = out.lines().next().unwrap();
        assert_eq!(first, "x,macs,gops");
    }

    #[test]
    fn run_dispatches_all_sim_commands() {
        for c in [
            "fig2a", "fig2b", "fig2c", "table1", "table2", "fig4", "fig-batch", "table3",
            "table3b", "table4", "table5", "table6", "fig5", "fig6", "headline",
        ] {
            let a = Args::parse(&argv(c)).unwrap();
            let out = run(&a).unwrap();
            assert!(!out.is_empty(), "{c}");
        }
    }

    #[test]
    fn schedule_acceptance_scenario_admits_all_three() {
        let a = Args::parse(&argv("schedule --models fc_big,conv_a,conv_b --tpus 4")).unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("fc_big"), "{out}");
        assert!(out.contains("conv_a"), "{out}");
        assert!(out.contains("conv_b"), "{out}");
        assert!(out.contains("admitted 3 queued 0 rejected 0"), "{out}");
        assert!(out.contains("4/4 TPUs used"), "{out}");
        assert!(!out.contains("queued:"), "{out}");
    }

    #[test]
    fn schedule_flags_weights_slos_csv() {
        let a = Args::parse(&argv(
            "schedule --models fc_small,conv_a --tpus 2 --weights 2,1 --slo-ms 1,- --csv",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.starts_with("model,weight,tpus"), "{out}");
        // bad weights arity errors
        let a = Args::parse(&argv("schedule --models fc_small --weights 1,2")).unwrap();
        assert!(run(&a).is_err());
        // unknown model errors
        let a = Args::parse(&argv("schedule --models bogus")).unwrap();
        assert!(run(&a).is_err());
    }

    #[test]
    fn schedule_reports_queued_and_rejected() {
        let a = Args::parse(&argv("schedule --models fc_huge,conv_big,fc_n3000 --tpus 4")).unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("queued:"), "{out}");
        assert!(out.contains("rejected:"), "{out}");
    }

    #[test]
    fn unknown_command_errors_with_usage() {
        let a = Args::parse(&argv("nope")).unwrap();
        let err = run(&a).unwrap_err().to_string();
        assert!(err.contains("USAGE"));
    }

    #[test]
    fn loadgen_csv_is_bit_identical_across_runs() {
        let cmd = "loadgen --models fc_small,conv_a --tpus 2 --seed 7 \
                   --requests 60 --arrivals poisson:900 --csv";
        let a = Args::parse(&argv(cmd)).unwrap();
        let first = run(&a).unwrap();
        let second = run(&a).unwrap();
        assert_eq!(first, second, "same seed must render the identical CSV");
        assert!(first.starts_with("model,arrivals,offered_hz"), "{first}");
        assert!(first.contains("fc_small"), "{first}");
        assert!(first.contains("conv_a"), "{first}");
        // a different seed changes the table
        let b = Args::parse(&argv(&cmd.replace("--seed 7", "--seed 8"))).unwrap();
        assert_ne!(first, run(&b).unwrap(), "seed must matter");
    }

    #[test]
    fn loadgen_spec_parses_per_model_arrivals_and_rejects_arity() {
        let a = Args::parse(&argv(
            "loadgen --models fc_small,conv_a --arrivals poisson:300,closed:4:0.001 \
             --requests 10 --max-batch 4 --max-wait-ms 1",
        ))
        .unwrap();
        let (_reg, alloc, spec) = loadgen_spec(&a).unwrap();
        assert!(alloc.replicate_leftover, "loadgen models replica fan-out by default");
        assert_eq!(spec.loads.len(), 2);
        assert_eq!(spec.loads[0].model, "fc_small");
        assert_eq!(spec.loads[1].arrivals.label(), "closed:4:0.001");
        assert_eq!(spec.policy.max_batch, 4);
        // wrong arity
        let a = Args::parse(&argv(
            "loadgen --models fc_small,conv_a,conv_b --arrivals poisson:1,poisson:2",
        ))
        .unwrap();
        assert!(loadgen_spec(&a).is_err());
        // bad process spec
        let a = Args::parse(&argv("loadgen --models fc_small --arrivals uniform:9")).unwrap();
        assert!(loadgen_spec(&a).is_err());
    }

    #[test]
    fn schedule_allow_sharing_admits_queued_tenant() {
        // fc_huge and fc_n2580 are the same 3-TPU model; on a 4-TPU pool
        // with conv_a, the whole-TPU auction must queue one of them
        let off = run(&Args::parse(&argv(
            "schedule --models fc_huge,fc_n2580,conv_a --tpus 4",
        ))
        .unwrap())
        .unwrap();
        assert!(off.contains("queued:"), "{off}");
        assert!(!off.contains("shared"), "{off}");
        assert!(!off.contains("swap_over_ms"), "whole-TPU table unchanged: {off}");

        let cmd = "schedule --models fc_huge,fc_n2580,conv_a --tpus 4 --allow-sharing";
        let on = run(&Args::parse(&argv(cmd)).unwrap()).unwrap();
        assert!(!on.contains("queued:"), "sharing must admit the loser: {on}");
        assert!(on.contains("shared 1/2"), "{on}");
        assert!(on.contains("swap_over_ms"), "{on}");
        assert!(on.contains("shared 2"), "footer counts shared grants: {on}");
        // two invocations render the identical plan
        assert_eq!(on, run(&Args::parse(&argv(cmd)).unwrap()).unwrap());
    }

    #[test]
    fn schedule_sharing_off_ignores_the_quantum_knob_byte_for_byte() {
        // the PR 3 compatibility invariant: without --allow-sharing the
        // unified search renders the exact whole-TPU table, whatever the
        // quantum is set to
        let base = "schedule --models fc_big,conv_a,conv_b --tpus 4";
        let plain = run(&Args::parse(&argv(base)).unwrap()).unwrap();
        let with_q =
            run(&Args::parse(&argv(&format!("{base} --quantum-us 50000"))).unwrap()).unwrap();
        assert_eq!(plain, with_q, "quantum must be inert with sharing off");
        assert!(!plain.contains("devices"), "{plain}");
        assert!(!plain.contains("grant"), "{plain}");
    }

    #[test]
    fn schedule_sharing_shows_devices_and_quantum() {
        let cmd = "schedule --models fc_small,fc_n512 --tpus 1 --allow-sharing \
                   --quantum-us 500";
        let out = run(&Args::parse(&argv(cmd)).unwrap()).unwrap();
        assert!(out.contains("devices"), "{out}");
        assert!(out.contains("shared 1/2"), "{out}");
        assert!(out.contains("quantum 500 us"), "{out}");
        assert_eq!(out, run(&Args::parse(&argv(cmd)).unwrap()).unwrap());
        // negative quantum is rejected
        let bad = Args::parse(&argv("schedule --models fc_small --quantum-us -5")).unwrap();
        assert!(run(&bad).is_err());
    }

    #[test]
    fn loadgen_quantum_cuts_swaps_deterministically() {
        let base = "loadgen --models fc_small,fc_n512 --tpus 1 --allow-sharing --seed 7 \
                    --requests 60 --arrivals poisson:900 --csv";
        let swaps_of = |out: &str| -> usize {
            let header = out.lines().next().unwrap();
            let col = header.split(',').position(|c| c == "swaps").unwrap();
            out.lines()
                .skip(1)
                .map(|l| l.split(',').nth(col).unwrap().parse::<usize>().unwrap())
                .sum()
        };
        let a = Args::parse(&argv(base)).unwrap();
        let no_quantum = run(&a).unwrap();
        assert!(no_quantum.lines().next().unwrap().contains("quantum_us"), "{no_quantum}");
        let q = Args::parse(&argv(&format!("{base} --quantum-us 1000000"))).unwrap();
        let with_quantum = run(&q).unwrap();
        assert_eq!(with_quantum, run(&q).unwrap(), "quantum runs must stay seed-stable");
        assert!(
            swaps_of(&with_quantum) < swaps_of(&no_quantum),
            "a 1s quantum must swap less:\n{no_quantum}\n{with_quantum}"
        );
    }

    #[test]
    fn schedule_prints_derived_batch_policy_for_slo_tenants() {
        let out = run(&Args::parse(&argv(
            "schedule --models fc_small,conv_a --tpus 2 --slo-ms 4,- --max-wait-ms 2",
        ))
        .unwrap())
        .unwrap();
        assert!(out.contains("batch policy fc_small"), "{out}");
        assert!(out.contains("max_wait 1.00"), "4 ms SLO -> 1 ms wait: {out}");
        assert!(!out.contains("batch policy conv_a"), "no SLO, no derived policy: {out}");
        // SLO-free invocations print no policy block at all
        let plain =
            run(&Args::parse(&argv("schedule --models fc_small,conv_a --tpus 2")).unwrap())
                .unwrap();
        assert!(!plain.contains("batch policy"), "{plain}");
    }

    #[test]
    fn loadgen_shared_deployment_reports_deterministic_swaps() {
        let cmd = "loadgen --models fc_small,fc_n512 --tpus 1 --allow-sharing --seed 7 \
                   --requests 60 --arrivals poisson:900 --csv";
        let a = Args::parse(&argv(cmd)).unwrap();
        let first = run(&a).unwrap();
        assert_eq!(first, run(&a).unwrap(), "shared loadgen must be seed-stable");
        let header = first.lines().next().unwrap();
        let swaps_col = header.split(',').position(|c| c == "swaps").unwrap();
        let grant_col = header.split(',').position(|c| c == "grant").unwrap();
        for line in first.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            assert!(cells[grant_col].starts_with("shared"), "{line}");
            let swaps: usize = cells[swaps_col].parse().unwrap();
            assert!(swaps >= 1, "shared tenants must report swaps: {line}");
        }
    }

    #[test]
    fn schedule_cache_budget_zero_is_byte_identical_and_nan_is_rejected() {
        let base = "schedule --models fc_small,fc_n512 --tpus 1 --allow-sharing";
        let plain = run(&Args::parse(&argv(base)).unwrap()).unwrap();
        let zero =
            run(&Args::parse(&argv(&format!("{base} --cache-budget-bytes 0"))).unwrap())
                .unwrap();
        assert_eq!(plain, zero, "a zero cache budget must be byte-inert");
        assert!(!plain.contains("cache_warm"), "{plain}");
        let on = run(&Args::parse(&argv(&format!(
            "{base} --cache-budget-bytes 1073741824 --prefetch"
        )))
        .unwrap())
        .unwrap();
        assert!(on.contains("cache_warm"), "{on}");
        assert!(on.contains("cache budget 1073741824 B + prefetch"), "{on}");
        // NaN / negative pinned switch costs die in arg parsing with a
        // clear message (satellite: validation used to be test-only)
        let nan =
            Args::parse(&argv("schedule --models fc_small --switch-cost-us NaN")).unwrap();
        let err = format!("{:#}", run(&nan).unwrap_err());
        assert!(err.contains("finite"), "{err}");
        let neg =
            Args::parse(&argv("schedule --models fc_small --switch-cost-us -3")).unwrap();
        let err = format!("{:#}", run(&neg).unwrap_err());
        assert!(err.contains("non-negative"), "{err}");
    }

    #[test]
    fn loadgen_cache_budget_warms_swaps_and_zero_budget_is_byte_identical() {
        let base = "loadgen --models fc_small,fc_n512 --tpus 1 --allow-sharing --seed 7 \
                    --requests 60 --arrivals poisson:900 --csv";
        let plain = run(&Args::parse(&argv(base)).unwrap()).unwrap();
        let zero =
            run(&Args::parse(&argv(&format!("{base} --cache-budget-bytes 0"))).unwrap())
                .unwrap();
        assert_eq!(plain, zero, "a zero cache budget must be byte-inert");
        assert!(!plain.lines().next().unwrap().contains("cache_hits"), "{plain}");

        let cmd = format!("{base} --cache-budget-bytes 1073741824");
        let a = Args::parse(&argv(&cmd)).unwrap();
        let on = run(&a).unwrap();
        assert_eq!(on, run(&a).unwrap(), "cache runs must stay seed-stable");
        let header = on.lines().next().unwrap();
        let col = |name: &str| {
            header
                .split(',')
                .position(|c| c == name)
                .unwrap_or_else(|| panic!("missing column {name}: {header}"))
        };
        let (swaps_c, hits_c, miss_c) =
            (col("swaps"), col("cache_hits"), col("cache_misses"));
        let rate_c = col("hit_rate");
        for line in on.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            let swaps: usize = cells[swaps_c].parse().unwrap();
            let hits: usize = cells[hits_c].parse().unwrap();
            let misses: usize = cells[miss_c].parse().unwrap();
            assert_eq!(hits + misses, swaps, "accounting invariant: {line}");
            assert_eq!(
                misses, 1,
                "a 1 GiB budget pins both tenants: only the compulsory first miss: {line}"
            );
            assert!(cells[rate_c].ends_with('%'), "{line}");
        }
    }

    #[test]
    fn loadgen_models_replica_fanout() {
        // --max-tpus-per-model 1 forces the leftover TPU to become a
        // data-parallel replica, which the sim must now model
        let cmd = "loadgen --models fc_small --tpus 2 --max-tpus-per-model 1 --seed 3 \
                   --requests 80 --arrivals poisson:2000 --csv";
        let a = Args::parse(&argv(cmd)).unwrap();
        let first = run(&a).unwrap();
        assert_eq!(first, run(&a).unwrap(), "fan-out table must be seed-stable");
        let header = first.lines().next().unwrap();
        let rep_col = header.split(',').position(|c| c == "replicas").unwrap();
        let row: Vec<&str> = first.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row[rep_col], "2", "{first}");
    }

    #[test]
    fn loadgen_marks_unadmitted_tenants() {
        // fc_n3000 can never fit on-chip -> rejected row, not a crash
        let a = Args::parse(&argv(
            "loadgen --models fc_small,fc_n3000 --tpus 2 --requests 10",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("rejected"), "{out}");
        assert!(out.contains("admitted"), "{out}");
    }

    #[test]
    fn chaos_csv_is_bit_identical_across_runs() {
        let cmd = "chaos --models fc_small,conv_a --tpus 2 --seed 7 --requests 80 \
                   --arrivals poisson:900 --kills 1 --stragglers 1 --overloads 1 --csv";
        let a = Args::parse(&argv(cmd)).unwrap();
        let first = run(&a).unwrap();
        let second = run(&a).unwrap();
        assert_eq!(first, second, "same seed must render the identical chaos CSV");
        assert!(first.starts_with("model,arrivals,replicas,events"), "{first}");
        assert!(first.contains("fc_small"), "{first}");
        // a different seed changes the run
        let b = Args::parse(&argv(&cmd.replace("--seed 7", "--seed 8"))).unwrap();
        assert_ne!(first, run(&b).unwrap(), "seed must matter");
    }

    #[test]
    fn chaos_rejects_closed_loop_arrivals() {
        let a = Args::parse(&argv(
            "chaos --models fc_small --tpus 1 --arrivals closed:4:0.001 --requests 10",
        ))
        .unwrap();
        let err = run(&a).unwrap_err().to_string();
        assert!(err.contains("open-loop"), "{err}");
    }

    #[test]
    fn chaos_marks_unadmitted_tenants() {
        let a = Args::parse(&argv(
            "chaos --models fc_small,fc_n3000 --tpus 2 --requests 20 --csv",
        ))
        .unwrap();
        let out = run(&a).unwrap();
        assert!(out.contains("rejected"), "{out}");
        assert!(out.contains("admitted"), "{out}");
    }

    #[test]
    fn chaos_reliability_columns_are_gated_off_by_default() {
        // flags off: the legacy header, byte-for-byte
        let legacy = run(&Args::parse(&argv(
            "chaos --models fc_small --tpus 2 --seed 7 --requests 40 \
             --arrivals poisson:900 --csv",
        ))
        .unwrap())
        .unwrap();
        let header = legacy.lines().next().unwrap();
        assert!(!header.contains("expired"), "{header}");
        assert!(!header.contains("recoveries"), "{header}");

        // flags on: expired + recoveries columns, /cN in events, exact
        // accounting, still bit-identical per seed
        let cmd = "chaos --models fc_small --tpus 2 --seed 7 --requests 40 \
                   --arrivals poisson:900 --crashes 1 --deadline-ms 50 --csv";
        let a = Args::parse(&argv(cmd)).unwrap();
        let first = run(&a).unwrap();
        assert_eq!(first, run(&a).unwrap(), "reliability CSV must be byte-identical");
        let header: Vec<&str> = first.lines().next().unwrap().split(',').collect();
        let row: Vec<&str> = first.lines().nth(1).unwrap().split(',').collect();
        let col = |name: &str| {
            row[header.iter().position(|c| *c == name).unwrap_or_else(|| panic!("{name}"))]
        };
        assert!(col("events").ends_with("/c1"), "{first}");
        let n = |name: &str| col(name).parse::<u64>().unwrap();
        assert_eq!(n("submitted"), n("completed") + n("shed") + n("expired"), "{first}");
        assert_eq!(n("admitted"), n("completed") + n("expired"), "{first}");
        assert_eq!(n("recoveries"), 1, "{first}");
    }

    #[test]
    fn chaos_live_hedge_flags_are_validated_at_parse() {
        let a = Args::parse(&argv(
            "chaos --models fc_small --tpus 1 --requests 10 --live \
             --hedge-p99-factor 0.5",
        ))
        .unwrap();
        let err = format!("{:#}", run(&a).unwrap_err());
        assert!(
            err.contains("hedge p99 factor must be finite and >= 1 (got 0.5)"),
            "{err}"
        );
        let b = Args::parse(&argv(
            "chaos --models fc_small --tpus 1 --requests 10 --live \
             --hedge-min-samples 0",
        ))
        .unwrap();
        let err = format!("{:#}", run(&b).unwrap_err());
        assert!(
            err.contains("hedge window must cover at least 1 sample (got 0)"),
            "{err}"
        );
    }

    #[test]
    fn recover_roundtrip_matches_uninterrupted_loadgen_csv() {
        let jpath = std::env::temp_dir()
            .join(format!("repro-cli-recover-{}.journal", std::process::id()));
        let _ = std::fs::remove_file(&jpath);
        let flags = "--models fc_small,conv_a --tpus 2 --seed 7 --requests 40 \
                     --arrivals poisson:900 --slo-ms 50,-";
        let baseline = run(&Args::parse(&argv(&format!("loadgen {flags} --csv"))).unwrap())
            .unwrap();
        run(&Args::parse(&argv(&format!(
            "recover --journal {} --write {flags}",
            jpath.display()
        )))
        .unwrap())
        .unwrap();
        let recovered = run(&Args::parse(&argv(&format!(
            "recover --journal {} {flags} --csv",
            jpath.display()
        )))
        .unwrap())
        .unwrap();
        assert_eq!(
            recovered, baseline,
            "the recovered table must be byte-identical to the uninterrupted run"
        );
        let _ = std::fs::remove_file(&jpath);
    }

    #[test]
    fn recover_needs_an_existing_journal() {
        let a = Args::parse(&argv("recover --models fc_small --tpus 1")).unwrap();
        let err = format!("{:#}", run(&a).unwrap_err());
        assert!(err.contains("repro recover needs --journal FILE"), "{err}");

        let missing = std::env::temp_dir()
            .join(format!("repro-cli-no-such-{}.journal", std::process::id()));
        let _ = std::fs::remove_file(&missing);
        let b = Args::parse(&argv(&format!(
            "recover --journal {} --models fc_small --tpus 1",
            missing.display()
        )))
        .unwrap();
        let err = format!("{:#}", run(&b).unwrap_err());
        assert!(err.contains("no journal to recover from"), "{err}");
    }

    #[test]
    fn loadgen_exports_are_byte_deterministic() {
        use crate::util::json::Json;

        let a = Args::parse(&argv(
            "loadgen --models fc_small,conv_a --tpus 4 --seed 7 --requests 60 \
             --arrivals poisson:700",
        ))
        .unwrap();
        let build = || {
            let cfg = a.config().unwrap();
            let (registry, alloc, spec) = loadgen_spec(&a).unwrap();
            let (_t, _plan, obs) = loadgen_table_obs(&registry, &cfg, &alloc, &spec).unwrap();
            (loadgen_trace_file(&obs).to_json(), loadgen_metrics_jsonl(&obs))
        };
        let (trace1, metrics1) = build();
        let (trace2, metrics2) = build();
        assert_eq!(trace1, trace2, "trace export must be byte-identical per seed");
        assert_eq!(metrics1, metrics2, "metrics export must be byte-identical per seed");

        // the file is Chrome-trace shaped, round-trips, and renders
        let file = crate::obs::TraceFile::parse(&trace1).unwrap();
        assert!(!file.events.is_empty());
        assert!(file.tracks.values().any(|n| n == "fc_small/requests"), "{:?}", file.tracks);
        let art = crate::trace::trace_ascii(&file, 60);
        assert!(art.contains("fc_small/requests"), "{art}");

        // one JSONL object per admitted tenant, streaming-histogram fields
        assert_eq!(metrics1.lines().count(), 2);
        for line in metrics1.lines() {
            let doc = Json::parse(line).unwrap();
            assert_eq!(doc.get("kind").and_then(Json::as_str), Some("loadgen"));
            assert_eq!(doc.get("requests").and_then(Json::as_u64), Some(60));
            assert!(doc.get("p99_s").and_then(Json::as_f64).unwrap() > 0.0);
        }
    }

    #[test]
    fn calibrate_csv_is_bit_identical_and_drift_recalibrates() {
        let a = Args::parse(&argv(
            "calibrate --models fc_small,conv_a --tpus 2 --seed 11 --drift fc_small --csv",
        ))
        .unwrap();
        let first = run(&a).unwrap();
        assert_eq!(first, run(&a).unwrap(), "calibrate CSV must be byte-identical per seed");
        assert!(
            first.starts_with(
                "window,model,samples,predicted_p99_ms,observed_p99_ms,drift_pct,action\n"
            ),
            "{first}"
        );
        assert!(first.contains("baseline"), "{first}");
        assert!(first.contains("recalibrate"), "sustained drift must fire: {first}");

        // naming an unregistered model is a flag error, not a silent no-op
        let bad =
            Args::parse(&argv("calibrate --models fc_small --tpus 1 --drift ghost")).unwrap();
        let err = run(&bad).unwrap_err().to_string();
        assert!(err.contains("unregistered model"), "{err}");
    }

    #[test]
    fn calibrate_without_drift_keeps_an_empty_ledger() {
        let a = Args::parse(&argv("calibrate --models fc_small,conv_a --tpus 2 --seed 11"))
            .unwrap();
        let out = run(&a).unwrap();
        assert_eq!(out, run(&a).unwrap(), "calibrate report must be seed-stable");
        assert!(out.contains("re-plan ledger: empty"), "{out}");
        assert!(!out.contains("recalibrate"), "{out}");
    }

    #[test]
    fn loadgen_calibrate_appends_report_after_unchanged_output() {
        let plain = Args::parse(&argv(
            "loadgen --models fc_small --tpus 1 --seed 9 --requests 80 --csv",
        ))
        .unwrap();
        let base = run(&plain).unwrap();
        let a = Args::parse(&argv(
            "loadgen --models fc_small --tpus 1 --seed 9 --requests 80 --csv --calibrate",
        ))
        .unwrap();
        let first = run(&a).unwrap();
        assert_eq!(first, run(&a).unwrap(), "--calibrate CSV must be byte-identical per seed");
        assert!(
            first.starts_with(&base),
            "--calibrate must append after the unchanged loadgen output"
        );
        assert!(first.len() > base.len(), "--calibrate must actually append a report");
        assert!(first.contains("window,model,samples"), "{first}");
    }

    #[test]
    fn pool_flag_validation_pins_quantum_and_cache_messages() {
        let a = Args::parse(&argv("schedule --models fc_small --quantum-us nan")).unwrap();
        let err = run(&a).unwrap_err().to_string();
        assert!(
            err.contains("--quantum-us must be a finite number of microseconds"),
            "{err}"
        );
        let b =
            Args::parse(&argv("schedule --models fc_small --cache-budget-bytes=-5")).unwrap();
        let err = run(&b).unwrap_err().to_string();
        assert!(err.contains("--cache-budget-bytes must be non-negative"), "{err}");
    }
}
