//! Ablations beyond the paper's main evaluation, implementing the
//! alternatives its §V-C/§VI discussion raises:
//!
//! * **Replication (data parallelism)** — "replicating the model and
//!   partitioning the input batch might be more efficient": k whole-model
//!   replicas, each on its own TPU (each paying its own host spill).
//! * **Hybrid CPU-TPU** — §VI future work: run the layers that would
//!   spill to host memory on the host *CPU* instead, as an extra pipeline
//!   stage.
//! * **Energy** — §VI future work: first-order energy model (2 W TPU at
//!   the paper's datasheet, host DRAM/PCIe power during streaming, CPU
//!   package power for the baseline) -> J/inference and EDP.

use crate::compiler::{place, Location};
use crate::config::SystemConfig;
use crate::device::CostModel;
use crate::hostexec::cpu_time_s;
use crate::link::Link;
use crate::model::Model;
use crate::pipeline::{simulate, single_tpu_latency_s, SimOptions, StageSpec};
use crate::profiler::best_partition;

/// Batched per-inference time of k whole-model replicas fed round-robin.
///
/// Each replica behaves like an independent single TPU (including its own
/// host-memory streaming); the host dispatch overhead is still
/// GIL-serialized across replicas, so k replicas saturate at one item per
/// `overhead` regardless of k.
pub fn replicate_per_item_s(model: &Model, k: usize, cfg: &SystemConfig, batch: usize) -> f64 {
    assert!(k >= 1);
    let t1 = single_tpu_latency_s(model, cfg);
    let oh = cfg.link.stage_overhead_s;
    let per_replica = (batch as f64 / k as f64).ceil();
    // replica-parallel service, host-serialized dispatch
    let service_bound = per_replica * (t1 + oh);
    let host_bound = batch as f64 * oh;
    service_bound.max(host_bound) / batch as f64
}

/// Segmentation (profiled, s TPUs) vs replication (k=s replicas) — the
/// paper's closing comparison, resolved quantitatively.
#[derive(Debug, Clone, Copy)]
pub struct ReplicationVsSegmentation {
    pub seg_per_item_s: f64,
    pub rep_per_item_s: f64,
    /// > 1 means segmentation wins.
    pub seg_advantage: f64,
}

pub fn replication_vs_segmentation(
    model: &Model,
    n_tpus: usize,
    cfg: &SystemConfig,
    batch: usize,
) -> ReplicationVsSegmentation {
    let seg = best_partition(model, cfg, n_tpus, batch).per_item_s;
    let rep = replicate_per_item_s(model, n_tpus, cfg, batch);
    ReplicationVsSegmentation {
        seg_per_item_s: seg,
        rep_per_item_s: rep,
        seg_advantage: rep / seg,
    }
}

/// Hybrid CPU-TPU pipeline (§VI future work): device-resident layers stay
/// on one TPU; the layers the compiler would spill to host memory run on
/// the host CPU as a second pipeline stage (no PCIe weight streaming at
/// all — the weights already live in host DRAM).
///
/// Returns batched per-inference time, or `None` if nothing spills (the
/// hybrid reduces to the single TPU).
pub fn hybrid_cpu_tpu_per_item_s(
    model: &Model,
    cfg: &SystemConfig,
    batch: usize,
) -> Option<f64> {
    let placement = place(&model.layers, &cfg.device);
    let first_host = placement.layers.iter().position(|l| l.location == Location::Host)?;
    // contiguous suffix split: TPU runs [0, first_host), CPU the rest
    // (host layers are a suffix for the paper's homogeneous chains,
    // modulo the tiny output layer which we also hand to the CPU)
    let tpu_layers = &model.layers[..first_host];
    let cpu_layers = Model::new("cpu_part", model.layers[first_host..].to_vec());
    if tpu_layers.is_empty() {
        return Some(cpu_time_s(&cpu_layers, &cfg.cpu) + cfg.link.stage_overhead_s);
    }
    let cm = CostModel::new(cfg.clone());
    let tpu_placement = place(tpu_layers, &cfg.device);
    let stages = vec![
        StageSpec {
            exec_s: cm.stage_cost(&tpu_placement).exec_s(),
            in_bytes: tpu_layers[0].input_elems(),
            out_bytes: tpu_layers.last().unwrap().output_elems(),
        },
        StageSpec {
            // CPU stage: no PCIe DMA in its service (data already on host)
            exec_s: cpu_time_s(&cpu_layers, &cfg.cpu),
            in_bytes: 0,
            out_bytes: 0,
        },
    ];
    let r = simulate(
        &stages,
        &Link::new(cfg.link.clone()),
        &SimOptions { batch, ..Default::default() },
    );
    Some(r.per_item_s(batch))
}

/// First-order energy model.
#[derive(Debug, Clone, Copy)]
pub struct EnergyReport {
    /// J per inference on a single Edge TPU (incl. host streaming power).
    pub single_tpu_j: f64,
    /// J per inference on s TPUs with the profiled split.
    pub pipeline_j: f64,
    /// J per inference on the host CPU baseline.
    pub cpu_j: f64,
    pub n_tpus: usize,
}

/// Power constants (datasheet / typical): TPU 2 W busy, 0.5 W idle;
/// host side (DRAM + PCIe + dispatch thread) 8 W while streaming/handling;
/// CPU package 65 W under load.
const TPU_BUSY_W: f64 = 2.0;
const TPU_IDLE_W: f64 = 0.5;
const HOST_IO_W: f64 = 8.0;
const CPU_W: f64 = 65.0;

pub fn energy(model: &Model, n_tpus: usize, cfg: &SystemConfig, batch: usize) -> EnergyReport {
    let cm = CostModel::new(cfg.clone());
    let p1 = place(&model.layers, &cfg.device);
    let c1 = cm.stage_cost(&p1);
    let single_tpu_j =
        c1.exec_s() * TPU_BUSY_W + (c1.host_stream_s + cfg.link.stage_overhead_s) * HOST_IO_W;

    let prof = best_partition(model, cfg, n_tpus, batch);
    let per_item = prof.per_item_s;
    // per item: each stage busy exec_i at 2 W; idle TPUs at 0.5 W for the
    // rest of the per-item window; host overhead at 8 W per stage handoff
    let busy: f64 = prof.stage_exec_s.iter().sum();
    let idle = (per_item * n_tpus as f64 - busy).max(0.0);
    let pipeline_j = busy * TPU_BUSY_W
        + idle * TPU_IDLE_W
        + n_tpus as f64 * cfg.link.stage_overhead_s * HOST_IO_W;

    let cpu_j = cpu_time_s(model, &cfg.cpu) * CPU_W;
    EnergyReport { single_tpu_j, pipeline_j, cpu_j, n_tpus }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::{conv_model, fc_model};

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    /// Pre-spill models: replication scales near-ideally and beats
    /// segmentation (no hops, no imbalance) — the paper's conjecture.
    #[test]
    fn replication_wins_pre_spill() {
        let cfg = cfg();
        for m in [fc_model(1000), conv_model(200)] {
            let r = replication_vs_segmentation(&m, 4, &cfg, 50);
            assert!(
                r.seg_advantage < 1.0,
                "{}: seg {:.2e} rep {:.2e}",
                m.name,
                r.seg_per_item_s,
                r.rep_per_item_s
            );
        }
    }

    /// Post-spill FC: every replica pays the full host-streaming penalty,
    /// while segmentation eliminates it -> segmentation wins big.
    #[test]
    fn segmentation_wins_post_spill() {
        let cfg = cfg();
        let m = fc_model(2100);
        let r = replication_vs_segmentation(&m, 3, &cfg, 50);
        assert!(r.seg_advantage > 4.0, "{r:?}");
    }

    #[test]
    fn replication_throughput_bounds() {
        let cfg = cfg();
        let m = fc_model(1000);
        let t1 = single_tpu_latency_s(&m, &cfg);
        let one = replicate_per_item_s(&m, 1, &cfg, 50);
        let four = replicate_per_item_s(&m, 4, &cfg, 48);
        assert!(one >= t1 / 1.001);
        // 4 replicas: at most 4x better, at least host-overhead-bound
        assert!(four >= cfg.link.stage_overhead_s - 1e-12);
        assert!(four >= one / 4.0 - 1e-12);
        assert!(four < one, "replication must help pre-spill");
    }

    #[test]
    fn hybrid_only_exists_post_spill() {
        let cfg = cfg();
        assert!(hybrid_cpu_tpu_per_item_s(&fc_model(1000), &cfg, 50).is_none());
        assert!(hybrid_cpu_tpu_per_item_s(&fc_model(2100), &cfg, 50).is_some());
    }

    /// Hybrid CPU-TPU beats the spilled single TPU for FC (CPU executes
    /// the spilled layers faster than PCIe can stream their weights).
    #[test]
    fn hybrid_beats_spilled_single_tpu_fc() {
        let cfg = cfg();
        let m = fc_model(2100);
        let t1 = single_tpu_latency_s(&m, &cfg);
        let hybrid = hybrid_cpu_tpu_per_item_s(&m, &cfg, 50).unwrap();
        assert!(hybrid < t1 / 2.0, "hybrid {hybrid} vs t1 {t1}");
    }

    #[test]
    fn energy_sanity() {
        let cfg = cfg();
        let m = conv_model(442); // fits on device, compute-heavy
        let e = energy(&m, 4, &cfg, 50);
        // TPU pipeline far more efficient than the 65 W CPU
        assert!(e.cpu_j > 10.0 * e.pipeline_j, "{e:?}");
        assert!(e.single_tpu_j > 0.0 && e.pipeline_j > 0.0);
        // FC post-spill: pipelining also saves energy (no PCIe streaming)
        let m = fc_model(2620);
        let e = energy(&m, 3, &cfg, 50);
        assert!(e.pipeline_j < e.single_tpu_j, "{e:?}");
    }
}
