//! Model segmentation: contiguous partitions of a layer chain onto `s`
//! TPUs (paper §V).
//!
//! A partition of `l` layers into `s` segments is identified by its `s-1`
//! **cut positions** (indices in `1..l` between layers).  There are
//! `C(l-1, s-1)` of them (paper footnote 3) — small enough for exhaustive
//! profiling on realistic chain lengths.

pub mod strategy;

use crate::model::{Layer, Model};

/// A contiguous partition, stored as ascending cut positions in `(0, l)`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Partition {
    pub cuts: Vec<usize>,
    pub n_layers: usize,
}

impl Partition {
    pub fn new(cuts: Vec<usize>, n_layers: usize) -> Self {
        let p = Partition { cuts, n_layers };
        p.validate();
        p
    }

    /// Single-segment (no cuts) partition.
    pub fn whole(n_layers: usize) -> Self {
        Partition::new(Vec::new(), n_layers)
    }

    pub fn validate(&self) {
        assert!(self.n_layers > 0, "empty model");
        let mut prev = 0usize;
        for &c in &self.cuts {
            assert!(c > prev && c < self.n_layers, "bad cut {c} (l={})", self.n_layers);
            prev = c;
        }
    }

    pub fn n_segments(&self) -> usize {
        self.cuts.len() + 1
    }

    /// `[start, end)` bounds of each segment.
    pub fn bounds(&self) -> Vec<(usize, usize)> {
        let mut b = Vec::with_capacity(self.n_segments());
        let mut start = 0;
        for &c in &self.cuts {
            b.push((start, c));
            start = c;
        }
        b.push((start, self.n_layers));
        b
    }

    /// Layer slices of each segment.
    pub fn segments<'a>(&self, model: &'a Model) -> Vec<&'a [Layer]> {
        assert_eq!(model.len(), self.n_layers);
        self.bounds().iter().map(|&(a, b)| &model.layers[a..b]).collect()
    }

    /// Paper-style label, e.g. "2+2+1" for cuts [2,4] of 5 layers.
    pub fn label(&self) -> String {
        self.bounds()
            .iter()
            .map(|(a, b)| (b - a).to_string())
            .collect::<Vec<_>>()
            .join("+")
    }
}

/// The compiler's default split: distribute the **layer count** evenly,
/// with earlier segments taking the smaller share (observed behaviour in
/// Tables III–IV: 5 layers on 3 TPUs -> 1+2+2, on 4 TPUs -> 1+1+1+2).
pub fn uniform_cuts(n_layers: usize, n_segments: usize) -> Partition {
    assert!(n_segments >= 1 && n_segments <= n_layers);
    let base = n_layers / n_segments;
    let rem = n_layers % n_segments;
    // first (n_segments - rem) segments get `base`, the rest get `base+1`
    let mut cuts = Vec::with_capacity(n_segments - 1);
    let mut pos = 0;
    for i in 0..n_segments - 1 {
        pos += if i < n_segments - rem { base } else { base + 1 };
        cuts.push(pos);
    }
    Partition::new(cuts, n_layers)
}

/// All `C(l-1, s-1)` contiguous partitions of `l` layers into `s` segments.
pub fn enumerate_partitions(n_layers: usize, n_segments: usize) -> Vec<Partition> {
    assert!(n_segments >= 1 && n_segments <= n_layers);
    let mut out = Vec::new();
    let mut cuts = Vec::with_capacity(n_segments - 1);
    fn rec(next: usize, left: usize, l: usize, cuts: &mut Vec<usize>, out: &mut Vec<Partition>) {
        if left == 0 {
            out.push(Partition::new(cuts.clone(), l));
            return;
        }
        // must leave room for `left` more cuts before l
        for c in next..=(l - left) {
            cuts.push(c);
            rec(c + 1, left - 1, l, cuts, out);
            cuts.pop();
        }
    }
    rec(1, n_segments - 1, n_layers, &mut cuts, &mut out);
    out
}

/// `C(n, k)` as u64 (small inputs only).
pub fn binomial(n: u64, k: u64) -> u64 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num = 1u64;
    for i in 0..k {
        num = num * (n - i) / (i + 1);
    }
    num
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::fc_model;

    #[test]
    fn uniform_matches_paper_tables() {
        // Table III: 5 layers / 3 TPUs -> first TPU gets only L1 (1+2+2)
        assert_eq!(uniform_cuts(5, 3).label(), "1+2+2");
        // Table IV: 5 layers / 4 TPUs -> last TPU gets two layers
        assert_eq!(uniform_cuts(5, 4).label(), "1+1+1+2");
        // 2 TPUs -> 2+3
        assert_eq!(uniform_cuts(5, 2).label(), "2+3");
        assert_eq!(uniform_cuts(5, 1).label(), "5");
        assert_eq!(uniform_cuts(6, 3).label(), "2+2+2");
    }

    #[test]
    fn enumeration_count_matches_formula() {
        // paper: (l-1)! / ((s-1)! (l-s)!) — 14 total for l=5, s=1..4
        let mut total = 0;
        for s in 1..=4 {
            let got = enumerate_partitions(5, s).len() as u64;
            assert_eq!(got, binomial(4, s as u64 - 1), "s={s}");
            total += got;
        }
        assert_eq!(total, 1 + 4 + 6 + 4); // the paper's "only 14 possibilities" (+1 for s=1)
    }

    #[test]
    fn bounds_cover_exactly() {
        let p = Partition::new(vec![1, 3], 5);
        assert_eq!(p.bounds(), vec![(0, 1), (1, 3), (3, 5)]);
        let m = fc_model(100);
        let segs = p.segments(&m);
        assert_eq!(segs.iter().map(|s| s.len()).sum::<usize>(), 5);
    }

    #[test]
    #[should_panic(expected = "bad cut")]
    fn rejects_out_of_range_cut() {
        Partition::new(vec![5], 5);
    }

    #[test]
    #[should_panic(expected = "bad cut")]
    fn rejects_duplicate_cut() {
        Partition::new(vec![2, 2], 5);
    }

    #[test]
    fn binomial_basics() {
        assert_eq!(binomial(4, 0), 1);
        assert_eq!(binomial(4, 2), 6);
        assert_eq!(binomial(19, 3), 969);
    }

    #[test]
    fn property_partitions_cover_contiguously() {
        crate::util::proptest::forall(128, |rng| {
            let l = rng.below(10) as usize + 1;
            let s = rng.below(l as u64) as usize + 1;
            let parts = enumerate_partitions(l, s);
            crate::check!(parts.len() as u64 == binomial(l as u64 - 1, s as u64 - 1), "l={l} s={s}");
            for p in &parts {
                let b = p.bounds();
                crate::check!(b[0].0 == 0, "first start");
                crate::check!(b.last().unwrap().1 == l, "last end");
                for w in b.windows(2) {
                    crate::check!(w[0].1 == w[1].0, "contiguous");
                }
                crate::check!(b.iter().all(|(a, z)| z > a), "non-empty segments");
            }
            // all partitions distinct
            let mut seen = std::collections::HashSet::new();
            for p in &parts {
                crate::check!(seen.insert(p.cuts.clone()), "duplicate {:?}", p.cuts);
            }
            Ok(())
        });
    }
}
