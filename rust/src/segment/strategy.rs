//! Segmentation strategies: how to choose the cut positions.
//!
//! * [`Strategy::Uniform`] — the compiler default (layer-count balance);
//!   reproduces the degeneracies of Tables III–IV.
//! * [`Strategy::MemoryBalanced`] — minimize the max per-segment weight
//!   footprint (the "logical next step" the paper discusses in §V-A and
//!   rejects as insufficient).
//! * [`Strategy::ProfiledExhaustive`] — the paper's contribution: profile
//!   every partition under the batched pipeline and keep the fastest.
//! * [`Strategy::ProfiledThreshold`] — Google-tool behaviour: first
//!   partition meeting a stage-imbalance threshold.

use crate::compiler::layer_footprint;
use crate::config::SystemConfig;
use crate::model::Model;
use crate::profiler;
use crate::segment::{enumerate_partitions, uniform_cuts, Partition};

/// A segmentation strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// Compiler default: even layer counts, earlier segments smaller.
    Uniform,
    /// Minimize max per-segment memory footprint.
    MemoryBalanced,
    /// Exhaustive profiling on a pipelined batch of the given size.
    ProfiledExhaustive { batch: usize },
    /// First partition whose (max-min) stage time <= threshold.
    ProfiledThreshold { batch: usize, max_delta_s: f64 },
}

impl Strategy {
    /// Choose a partition of `model` into `n_segments`.
    pub fn partition(&self, model: &Model, n_segments: usize, cfg: &SystemConfig) -> Partition {
        match *self {
            Strategy::Uniform => uniform_cuts(model.len(), n_segments),
            Strategy::MemoryBalanced => memory_balanced(model, n_segments, cfg),
            Strategy::ProfiledExhaustive { batch } => {
                profiler::best_partition(model, cfg, n_segments, batch).partition
            }
            Strategy::ProfiledThreshold { batch, max_delta_s } => {
                profiler::threshold_search(model, cfg, n_segments, batch, max_delta_s).partition
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Uniform => "uniform",
            Strategy::MemoryBalanced => "memory-balanced",
            Strategy::ProfiledExhaustive { .. } => "profiled-exhaustive",
            Strategy::ProfiledThreshold { .. } => "profiled-threshold",
        }
    }
}

/// Minimize the maximum per-segment footprint over all contiguous
/// partitions (exhaustive — the space is C(l-1, s-1)).
fn memory_balanced(model: &Model, n_segments: usize, cfg: &SystemConfig) -> Partition {
    let fp: Vec<u64> =
        model.layers.iter().map(|l| layer_footprint(l, &cfg.device)).collect();
    enumerate_partitions(model.len(), n_segments)
        .into_iter()
        .min_by_key(|p| {
            p.bounds()
                .iter()
                .map(|&(a, b)| fp[a..b].iter().sum::<u64>())
                .max()
                .unwrap_or(0)
        })
        .expect("at least one partition")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::place_partition;
    use crate::model::synthetic::{conv_model, fc_model};

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn uniform_is_compiler_default() {
        let m = fc_model(1140);
        assert_eq!(Strategy::Uniform.partition(&m, 3, &cfg()).label(), "1+2+2");
    }

    /// Memory balance moves the big layers off the degenerate tiny-first
    /// segment (paper: uniform 3-TPU FC leaves TPU1 nearly empty).
    #[test]
    fn memory_balanced_fixes_fc_degeneracy() {
        let m = fc_model(2100);
        let p = Strategy::MemoryBalanced.partition(&m, 3, &cfg());
        // first segment takes L1+L2 (the 64n + n^2 pair), not just L1
        assert_eq!(p.bounds()[0], (0, 2), "{p:?}");
        // and the result fits entirely on-device where uniform spills
        let segs = p.segments(&m);
        let rep = place_partition(&segs, &cfg().device);
        assert!(!rep.uses_host());
    }

    #[test]
    fn profiled_strategies_return_requested_arity() {
        let m = conv_model(592);
        for s in 1..=4 {
            let p = Strategy::ProfiledExhaustive { batch: 50 }.partition(&m, s, &cfg());
            assert_eq!(p.n_segments(), s);
            let p = Strategy::ProfiledThreshold { batch: 50, max_delta_s: 1e-3 }
                .partition(&m, s, &cfg());
            assert_eq!(p.n_segments(), s);
        }
    }

    /// Heterogeneous models (paper §V-C's motivation for profiling over a
    /// "multivariable optimisation"): with mixed conv/fc layers, memory
    /// balance and workload balance disagree, and only the profiled
    /// search resolves the trade-off.
    #[test]
    fn hetero_model_profiling_beats_memory_balance() {
        use crate::model::synthetic::conv_fc_model;
        // low-overhead host (a C++ runtime rather than Python threads) so
        // stage compute/stream balance — not the GIL — is the bottleneck
        let mut cfg = cfg();
        cfg.link.stage_overhead_s = 20e-6;
        // 3 compute-heavy convs (150 KiB of weights each) + one
        // memory-heavy dense layer (4.2 MiB) + small head: memory balance
        // isolates the dense layer; workload balance must split the convs
        let m = conv_fc_model(128, 3, 16, 16, &[128, 10]);
        let table = profiler::SegmentCostTable::build(&m, &cfg);
        let mb = Strategy::MemoryBalanced.partition(&m, 3, &cfg);
        let mb_prof = profiler::profile_partition(&m, &table, &mb, &cfg, 50);
        let best = profiler::best_partition(&m, &cfg, 3, 50);
        assert!(
            best.per_item_s < mb_prof.per_item_s * 0.999,
            "profiled {:?} ({:.1}us) should strictly beat memory-balanced {:?} ({:.1}us)",
            best.partition.cuts,
            best.per_item_s * 1e6,
            mb.cuts,
            mb_prof.per_item_s * 1e6,
        );
        assert_ne!(best.partition.cuts, mb.cuts, "expected strategies to diverge");
    }

    /// Memory balance alone is NOT sufficient (paper §V-A: "would not
    /// consider that ... the one that distributes the workload more evenly
    /// is preferable") — profiled must be at least as fast everywhere.
    #[test]
    fn property_profiled_beats_or_ties_memory_balanced() {
        crate::util::proptest::forall(32, |rng| {
            let cfg = cfg();
            let m = if rng.below(2) == 0 {
                fc_model(rng.below(2400) + 200)
            } else {
                conv_model(rng.below(600) + 40)
            };
            let s = rng.below(3) as usize + 2;
            let batch = 50;
            let table = profiler::SegmentCostTable::build(&m, &cfg);
            let mb = Strategy::MemoryBalanced.partition(&m, s, &cfg);
            let mb_prof = profiler::profile_partition(&m, &table, &mb, &cfg, batch);
            let best = profiler::best_partition(&m, &cfg, s, batch);
            crate::check!(
                best.per_item_s <= mb_prof.per_item_s + 1e-12,
                "{} s={s}: best={} mb={}",
                m.name,
                best.per_item_s,
                mb_prof.per_item_s
            );
            Ok(())
        });
    }
}
