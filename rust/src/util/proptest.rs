//! Tiny property-testing harness (proptest is not in the offline vendor
//! set): run a property over many deterministic random cases and report the
//! seed of the first failing case so it can be replayed.
//!
//! ```ignore
//! forall(256, |rng| {
//!     let n = rng.below(100) + 1;
//!     check!(some_invariant(n), "n={n}");
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Run `prop` over `cases` deterministic cases.  Panics (test failure) with
/// the case seed on the first `Err`.
pub fn forall(cases: u64, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        // decouple case streams; replay one case with `replay(seed, prop)`
        let seed = 0x5EED_0000_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case {case} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replay {seed:#x} failed: {msg}");
    }
}

/// `check!(cond, "context {x}")` inside a `forall` property.
#[macro_export]
macro_rules! check {
    ($cond:expr, $($ctx:tt)+) => {
        if !$cond {
            return Err(format!("{} — {}", stringify!($cond), format!($($ctx)+)));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(stringify!($cond).to_string());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0u64;
        forall(64, |rng| {
            n += 1;
            let v = rng.below(10);
            check!(v < 10, "v={v}");
            Ok(())
        });
        assert_eq!(n, 64);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        forall(64, |rng| {
            let v = rng.below(100);
            check!(v < 90, "v={v}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        forall(8, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        forall(8, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
