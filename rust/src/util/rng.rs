//! Deterministic PRNG (no `rand` crate in the offline vendor set).
//!
//! splitmix64-seeded xoshiro256++ — the standard, well-tested generator
//! family; deterministic across platforms, which the property tests and
//! workload generators rely on.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that small/contiguous seeds give
    /// well-separated states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`.  Uses rejection sampling (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Random int8 (full range), for activation tensors.
    pub fn i8(&mut self) -> i8 {
        (self.next_u64() & 0xFF) as u8 as i8
    }

    /// Vector of random int8.
    pub fn i8_vec(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.i8()).collect()
    }

    /// Exponentially distributed with mean `mean` (for arrival processes).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(11);
        let mean = (0..20_000).map(|_| r.exp(2.0)).sum::<f64>() / 20_000.0;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(v, (0..20).collect::<Vec<_>>()); // astronomically unlikely
    }
}
