//! Minimal JSON parser/serializer.
//!
//! Substrate module: the offline vendor set has no `serde_json`, and the
//! runtime must read `artifacts/manifest.json` (written by `aot.py`) plus
//! the repo's config files.  Full RFC 8259 input grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null); numbers are held as
//! `f64`, which is exact for every integer the manifest contains (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` chain helper: `j.at(&["models", "fc_n256", "macs"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Serialize compactly (stable field order via BTreeMap).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs: accept lone surrogates as U+FFFD
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            Json::parse(r#""a\n\t\"\\A""#).unwrap(),
            Json::Str("a\n\t\"\\A".into())
        );
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": {"d": true}}"#).unwrap();
        assert_eq!(j.at(&["c", "d"]), Some(&Json::Bool(true)));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":false,"n":null,"big":72708710400}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
        assert_eq!(j.get("big").unwrap().as_u64(), Some(72_708_710_400));
    }

    #[test]
    fn integers_exact() {
        // macs values in the manifest must round-trip exactly
        for v in [0u64, 1, 7_270_871_040, 2u64.pow(52)] {
            let j = Json::parse(&format!("{v}")).unwrap();
            assert_eq!(j.as_u64(), Some(v));
        }
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ✓"));
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }
}
