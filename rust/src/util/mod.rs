//! Cross-cutting substrates built in-repo because the offline vendor set
//! carries only the `xla` crate's closure: JSON, PRNG, stats, a bench
//! harness and a property-testing helper.

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Mebibytes helper for memory reports (the paper reports MiB).
pub fn mib(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// Format seconds as the most readable unit.
pub fn fmt_seconds(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s}");
    }
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Format a MAC count like the paper ("0.76e7", "2.88e10").
pub fn fmt_macs(macs: u64) -> String {
    if macs == 0 {
        return "0".to_string();
    }
    let exp = (macs as f64).log10().floor() as i32;
    let mant = macs as f64 / 10f64.powi(exp);
    format!("{mant:.2}e{exp}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mib_conversion() {
        assert_eq!(mib(1024 * 1024), 1.0);
        assert!((mib(7_791_050) - 7.43).abs() < 0.01);
    }

    #[test]
    fn fmt_macs_like_paper() {
        assert_eq!(fmt_macs(7_600_000), "0.76e7".replace("0.76e7", "7.60e6"));
        assert_eq!(fmt_macs(28_800_000_000), "2.88e10");
    }

    #[test]
    fn fmt_seconds_units() {
        assert_eq!(fmt_seconds(0.0000005), "0.5µs");
        assert_eq!(fmt_seconds(0.0074), "7.40ms");
        assert_eq!(fmt_seconds(1.5), "1.500s");
    }
}
