//! Summary statistics and latency histograms for benches and serving
//! metrics (no external stats crates offline).

/// Online summary over f64 samples, plus exact percentiles on demand.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Exact percentile via nearest-rank on a sorted copy; `q` in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-bucket log-scale latency histogram (1 µs .. ~100 s), cheap enough
/// for the serving hot path (single atomic-free add; wrap in a mutex or
/// per-worker instance for concurrency).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [BASE * GROWTH^i, BASE * GROWTH^(i+1))
    counts: Vec<u64>,
    total: u64,
    sum_s: f64,
    max_s: f64,
}

const BASE_S: f64 = 1e-6;
const GROWTH: f64 = 1.25;
const NBUCKETS: usize = 90; // 1.25^90 * 1us ~ 5e2 s

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { counts: vec![0; NBUCKETS], total: 0, sum_s: 0.0, max_s: 0.0 }
    }

    fn bucket(v_s: f64) -> usize {
        if v_s <= BASE_S {
            return 0;
        }
        let b = (v_s / BASE_S).ln() / GROWTH.ln();
        (b as usize).min(NBUCKETS - 1)
    }

    pub fn record(&mut self, v_s: f64) {
        self.counts[Self::bucket(v_s)] += 1;
        self.total += 1;
        self.sum_s += v_s;
        self.max_s = self.max_s.max(v_s);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum_s / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max_s
    }

    /// Percentile estimate from bucket upper bounds (bounded ~25% relative
    /// error by construction).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = ((q / 100.0) * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return BASE_S * GROWTH.powi(i as i32 + 1);
            }
        }
        self.max_s
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_s += other.sum_s;
        self.max_s = self.max_s.max(other.max_s);
    }

    /// The histogram of samples recorded since `earlier` was cloned off
    /// this same stream: per-bucket saturating subtraction.  Lets the
    /// online calibrator window a lifetime histogram by diffing
    /// successive snapshots instead of instrumenting the hot path.
    /// `max` is carried from `self` (an upper bound for the window —
    /// the per-window maximum is not recoverable from two snapshots).
    pub fn delta_since(&self, earlier: &LatencyHistogram) -> LatencyHistogram {
        let counts: Vec<u64> = self
            .counts
            .iter()
            .zip(&earlier.counts)
            .map(|(a, b)| a.saturating_sub(*b))
            .collect();
        LatencyHistogram {
            total: self.total.saturating_sub(earlier.total),
            sum_s: (self.sum_s - earlier.sum_s).max(0.0),
            max_s: self.max_s,
            counts,
        }
    }
}

/// Two-bank windowed variant of [`LatencyHistogram`] for online
/// calibration: `record` feeds the hot bank, `reset_window` retires the
/// hot bank into the warm slot (dropping the bank before it), and
/// `recent` reads the merge of the two newest banks.  A lifetime
/// histogram averages a mid-run service-time shift away under its old
/// counts; this one forgets everything older than two windows, so a
/// drifted stage's p99 shows up after at most two `reset_window` calls.
#[derive(Debug, Clone, Default)]
pub struct WindowedHistogram {
    /// In-progress window, receiving live samples.
    hot: LatencyHistogram,
    /// The last completed window (the decayed history: one bank deep).
    warm: LatencyHistogram,
    /// Completed windows so far (`reset_window` calls).
    windows: u64,
}

impl WindowedHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample into the current window.
    pub fn record(&mut self, v_s: f64) {
        self.hot.record(v_s);
    }

    /// Fold a pre-bucketed batch of samples (e.g. a [`delta_since`]
    /// window of a lifetime histogram) into the current window — O(1)
    /// in the number of samples.
    ///
    /// [`delta_since`]: LatencyHistogram::delta_since
    pub fn absorb(&mut self, batch: &LatencyHistogram) {
        self.hot.merge(batch);
    }

    /// Close the current window: the hot bank becomes the warm bank and
    /// the previous warm bank is dropped (samples age out after two
    /// windows).  O(1) bank swap, no per-sample work.
    pub fn reset_window(&mut self) {
        self.warm = std::mem::take(&mut self.hot);
        self.windows += 1;
    }

    /// The recent view: last completed window merged with the
    /// in-progress one.  Percentiles over this never include samples
    /// older than two windows.
    pub fn recent(&self) -> LatencyHistogram {
        let mut merged = self.warm.clone();
        merged.merge(&self.hot);
        merged
    }

    /// Samples visible in the recent view.
    pub fn recent_count(&self) -> u64 {
        self.warm.count() + self.hot.count()
    }

    /// Samples recorded in the in-progress window only (excludes the
    /// warm bank) — the calibrator's per-window traffic gate, so a
    /// sparse window is skipped even when the previous window was busy.
    pub fn window_count(&self) -> u64 {
        self.hot.count()
    }

    /// Percentile over the recent view (NaN while empty).
    pub fn recent_percentile(&self, q: f64) -> f64 {
        self.recent().percentile(q)
    }

    /// Completed windows so far.
    pub fn windows(&self) -> u64 {
        self.windows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_edges() {
        let mut s = Summary::new();
        s.add(10.0);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn histogram_percentiles_bracket_truth() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 100ms uniform
        }
        let p50 = h.percentile(50.0);
        assert!(p50 > 0.03 && p50 < 0.08, "p50={p50}");
        let p99 = h.percentile(99.0);
        assert!(p99 > 0.07 && p99 < 0.15, "p99={p99}");
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.050).abs() < 0.002);
    }

    #[test]
    fn histogram_quantiles_track_exact_percentiles() {
        // golden check against the exact full-sample Summary on a seeded
        // log-uniform stream (10 us .. 1 s): a log-bucket estimate returns
        // its bucket's upper bound, so it must sit within one GROWTH
        // factor of the exact nearest-rank percentile (plus a little rank
        // slack between the two conventions)
        let mut h = LatencyHistogram::new();
        let mut s = Summary::new();
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        for _ in 0..5000 {
            state = state
                .wrapping_mul(6_364_136_223_846_793_005)
                .wrapping_add(1_442_695_040_888_963_407);
            let u = (state >> 11) as f64 / (1u64 << 53) as f64;
            let v = 1e-5 * 1e5f64.powf(u);
            h.record(v);
            s.add(v);
        }
        for q in [50.0, 90.0, 95.0, 99.0, 99.9] {
            let est = h.percentile(q);
            let exact = s.percentile(q);
            assert!(
                est >= exact * 0.75 && est <= exact * 1.35,
                "q={q}: histogram {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn histogram_merge_equals_combined_stream() {
        // merging two shards must quantile-match one histogram fed the
        // union of both streams
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for i in 1..=400u64 {
            let v = i as f64 * 2.5e-4;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for q in [50.0, 95.0, 99.0, 99.9] {
            assert_eq!(a.percentile(q), all.percentile(q), "q={q}");
        }
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert_eq!(a.max(), all.max());
    }

    /// Seeded LCG stream for the cross-shard tests (self-contained so the
    /// shard split is reproducible without the crate RNG).
    fn seeded_stream(seed: u64, n: usize) -> Vec<f64> {
        let mut state = seed;
        (0..n)
            .map(|_| {
                state = state
                    .wrapping_mul(6_364_136_223_846_793_005)
                    .wrapping_add(1_442_695_040_888_963_407);
                let u = (state >> 11) as f64 / (1u64 << 53) as f64;
                // log-uniform 10 us .. 1 s, like real latency tails
                1e-5 * 1e5f64.powf(u)
            })
            .collect()
    }

    #[test]
    fn histogram_cross_shard_merge_is_order_and_shard_invariant() {
        // per-worker shards merged in any order must agree exactly with a
        // single histogram fed the whole stream — the contract that lets
        // the pool aggregate per-replica ServeMetrics without a shared
        // lock on the hot path
        for seed in [1u64, 7, 0xBAD5EED] {
            let samples = seeded_stream(seed, 3000);
            for nshards in [2usize, 3, 5] {
                let mut shards = vec![LatencyHistogram::new(); nshards];
                let mut all = LatencyHistogram::new();
                for (i, &v) in samples.iter().enumerate() {
                    shards[i % nshards].record(v);
                    all.record(v);
                }
                // fold in reverse order: merge must be order-insensitive
                let mut merged = LatencyHistogram::new();
                for shard in shards.iter().rev() {
                    merged.merge(shard);
                }
                assert_eq!(merged.count(), all.count(), "seed {seed} shards {nshards}");
                // sums re-associate across shards, so allow float slack
                assert!(
                    (merged.mean() - all.mean()).abs() <= 1e-9 * all.mean().abs(),
                    "seed {seed} shards {nshards}: mean {} vs {}",
                    merged.mean(),
                    all.mean()
                );
                assert_eq!(merged.max(), all.max(), "seed {seed} shards {nshards}");
                for q in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
                    assert_eq!(
                        merged.percentile(q),
                        all.percentile(q),
                        "seed {seed} shards {nshards} q={q}"
                    );
                }
            }
        }
    }

    #[test]
    fn histogram_percentiles_are_monotone_in_q() {
        // estimates come from cumulative bucket counts, so they must never
        // decrease as q grows — on a fresh stream and on a merged one
        let mut h = LatencyHistogram::new();
        for v in seeded_stream(42, 2000) {
            h.record(v);
        }
        let mut other = LatencyHistogram::new();
        for v in seeded_stream(43, 500) {
            other.record(v);
        }
        for hist in [&h, &{
            let mut m = h.clone();
            m.merge(&other);
            m
        }] {
            let mut last = f64::NEG_INFINITY;
            let mut q = 0.0;
            while q <= 100.0 {
                let p = hist.percentile(q);
                assert!(p.is_finite(), "q={q}");
                assert!(p >= last, "percentile must be monotone: q={q} {p} < {last}");
                last = p;
                q += 0.5;
            }
        }
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(1e-3);
        b.record(2e-3);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.max() == 2e-3);
    }

    #[test]
    fn histogram_delta_since_windows_a_lifetime_stream() {
        let mut life = LatencyHistogram::new();
        for _ in 0..500 {
            life.record(1e-3);
        }
        let snap = life.clone();
        for _ in 0..50 {
            life.record(1e-2);
        }
        let delta = life.delta_since(&snap);
        assert_eq!(delta.count(), 50);
        assert!(delta.percentile(50.0) > 5e-3, "delta sees only the new samples");
        assert!((delta.mean() - 1e-2).abs() < 1e-9);
        // absorbing the delta into a windowed histogram feeds its hot bank
        let mut w = WindowedHistogram::new();
        w.absorb(&delta);
        assert_eq!(w.recent_count(), 50);
        assert!(w.recent_percentile(99.0) > 5e-3);
        // identical snapshots diff to an empty window
        assert_eq!(life.delta_since(&life).count(), 0);
    }

    #[test]
    fn windowed_histogram_detects_drift_a_lifetime_histogram_averages_away() {
        // regression: a stage that served 1 ms for its whole life and
        // then drifts to 10 ms must surface the new p99 within two
        // windows.  The lifetime histogram keeps reporting the old p99
        // (the drifted tail is outvoted by history); the two-bank
        // windowed histogram forgets that history.
        let mut lifetime = LatencyHistogram::new();
        let mut windowed = WindowedHistogram::new();
        for _ in 0..10_000 {
            lifetime.record(1e-3);
            windowed.record(1e-3);
        }
        // drift hits: 100-sample windows of 10 ms service time
        let mut detected_after = None;
        for w in 1..=4u64 {
            windowed.reset_window();
            for _ in 0..100 {
                lifetime.record(1e-2);
                windowed.record(1e-2);
            }
            if detected_after.is_none() && windowed.recent_percentile(99.0) > 5e-3 {
                detected_after = Some(w);
            }
        }
        // the windowed view sees the drift within two windows...
        assert!(
            matches!(detected_after, Some(w) if w <= 2),
            "drift not detected: {detected_after:?}"
        );
        // ...while the lifetime histogram still reports the stale p99
        assert!(
            lifetime.percentile(99.0) < 2e-3,
            "lifetime p99 {} should be dominated by pre-drift history",
            lifetime.percentile(99.0)
        );
    }

    #[test]
    fn windowed_histogram_reset_ages_out_after_two_banks() {
        let mut w = WindowedHistogram::new();
        w.record(1e-3);
        assert_eq!(w.recent_count(), 1);
        w.reset_window(); // sample now in the warm bank: still visible
        assert_eq!(w.recent_count(), 1);
        assert_eq!(w.windows(), 1);
        w.reset_window(); // two windows old: gone
        assert_eq!(w.recent_count(), 0);
        assert!(w.recent_percentile(99.0).is_nan());
        // recent() merges both banks
        w.record(1e-3);
        w.reset_window();
        w.record(4e-3);
        let r = w.recent();
        assert_eq!(r.count(), 2);
        assert_eq!(r.max(), 4e-3);
    }
}
