//! Micro-benchmark harness driving the `cargo bench` targets (criterion is
//! not in the offline vendor set).
//!
//! Behaviour: warm-up, then timed iterations until both a minimum iteration
//! count and a minimum wall-time are reached; reports mean / p50 / p95 and
//! throughput.  `black_box` prevents the optimizer from deleting the
//! measured work.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats::Summary;

pub use std::hint::black_box;

/// One benchmark result row.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.3}ms", s * 1e3)
    } else {
        format!("{:8.3}s ", s)
    }
}

/// Benchmark runner: collects rows, prints a criterion-like table.
pub struct Bencher {
    rows: Vec<BenchResult>,
    min_iters: usize,
    max_iters: usize,
    min_time: Duration,
    warmup: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // keep `cargo bench` wall-time sane across the many targets
        Bencher {
            rows: Vec::new(),
            min_iters: 10,
            max_iters: 100_000,
            min_time: Duration::from_millis(300),
            warmup: Duration::from_millis(100),
        }
    }

    pub fn with_budget(mut self, min_time: Duration, warmup: Duration) -> Self {
        self.min_time = min_time;
        self.warmup = warmup;
        self
    }

    /// Time `f` repeatedly; the closure's return value is black-boxed.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warm-up
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            std_black_box(f());
        }
        let mut s = Summary::new();
        let start = Instant::now();
        let mut iters = 0usize;
        while (iters < self.min_iters || start.elapsed() < self.min_time)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            std_black_box(f());
            s.add(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        self.rows.push(BenchResult {
            name: name.to_string(),
            iters,
            mean_s: s.mean(),
            p50_s: s.p50(),
            p95_s: s.p95(),
            min_s: s.min(),
        });
        self.rows.last().unwrap()
    }

    /// Print all rows as an aligned table (called at the end of each bench
    /// binary; `cargo bench` output is this table).
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>8} {:>10} {:>10} {:>10} {:>10}",
            "benchmark", "iters", "mean", "p50", "p95", "min"
        );
        for r in &self.rows {
            println!(
                "{:<44} {:>8} {} {} {} {}",
                r.name,
                r.iters,
                fmt_time(r.mean_s),
                fmt_time(r.p50_s),
                fmt_time(r.p95_s),
                fmt_time(r.min_s),
            );
        }
    }

    pub fn rows(&self) -> &[BenchResult] {
        &self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new()
            .with_budget(Duration::from_millis(20), Duration::from_millis(5));
        let r = b.bench("noop-vec", || vec![0u8; 64]).clone();
        assert!(r.iters >= 10);
        assert!(r.mean_s > 0.0 && r.mean_s < 0.01);
        assert!(r.p50_s <= r.p95_s);
    }

    #[test]
    fn report_does_not_panic() {
        let mut b = Bencher::new()
            .with_budget(Duration::from_millis(5), Duration::from_millis(1));
        b.bench("x", || 1 + 1);
        b.report("t");
    }
}
