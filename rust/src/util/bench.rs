//! Micro-benchmark harness driving the `cargo bench` targets (criterion is
//! not in the offline vendor set).
//!
//! Behaviour: warm-up, then timed iterations until both a minimum iteration
//! count and a minimum wall-time are reached; reports mean / p50 / p95 and
//! throughput.  `black_box` prevents the optimizer from deleting the
//! measured work.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats::Summary;

pub use std::hint::black_box;

/// One benchmark result row.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.3}ms", s * 1e3)
    } else {
        format!("{:8.3}s ", s)
    }
}

/// Benchmark runner: collects rows, prints a criterion-like table.
pub struct Bencher {
    rows: Vec<BenchResult>,
    min_iters: usize,
    max_iters: usize,
    min_time: Duration,
    warmup: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // keep `cargo bench` wall-time sane across the many targets
        Bencher {
            rows: Vec::new(),
            min_iters: 10,
            max_iters: 100_000,
            min_time: Duration::from_millis(300),
            warmup: Duration::from_millis(100),
        }
    }

    pub fn with_budget(mut self, min_time: Duration, warmup: Duration) -> Self {
        self.min_time = min_time;
        self.warmup = warmup;
        self
    }

    /// Time `f` repeatedly; the closure's return value is black-boxed.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warm-up
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            std_black_box(f());
        }
        let mut s = Summary::new();
        let start = Instant::now();
        let mut iters = 0usize;
        while (iters < self.min_iters || start.elapsed() < self.min_time)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            std_black_box(f());
            s.add(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        self.rows.push(BenchResult {
            name: name.to_string(),
            iters,
            mean_s: s.mean(),
            p50_s: s.p50(),
            p95_s: s.p95(),
            min_s: s.min(),
        });
        self.rows.last().unwrap()
    }

    /// Measure the fixed-work calibration scenario every bench binary
    /// shares (`calibration/xoshiro_1m`: one million PRNG steps).  The
    /// regression gate (`scripts/bench_check.py`) divides every scenario
    /// by it, so it compares machine-normalized ratios instead of
    /// absolute wall times — the loop must therefore be bit-identical
    /// across binaries, which is why it lives here and not in them.
    pub fn bench_calibration(&mut self) -> &BenchResult {
        self.bench("calibration/xoshiro_1m", || {
            let mut rng = super::rng::Rng::new(0x5EED);
            let mut acc = 0u64;
            for _ in 0..1_000_000 {
                acc ^= rng.next_u64();
            }
            acc
        })
    }

    /// Shrink the measurement budget when `BENCH_QUICK` is set — the CI
    /// bench job's quick mode: enough iterations for the regression gate
    /// (`scripts/bench_check.py`), not publication statistics.
    pub fn quick_from_env(self) -> Self {
        if std::env::var_os("BENCH_QUICK").is_some() {
            self.with_budget(Duration::from_millis(40), Duration::from_millis(10))
        } else {
            self
        }
    }

    /// Print all rows as an aligned table (called at the end of each bench
    /// binary; `cargo bench` output is this table).  With `BENCH_JSON_DIR`
    /// set, additionally writes `BENCH_<title>.json` there (the CI bench
    /// artifact; schema in DESIGN.md §11).
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>8} {:>10} {:>10} {:>10} {:>10}",
            "benchmark", "iters", "mean", "p50", "p95", "min"
        );
        for r in &self.rows {
            println!(
                "{:<44} {:>8} {} {} {} {}",
                r.name,
                r.iters,
                fmt_time(r.mean_s),
                fmt_time(r.p50_s),
                fmt_time(r.p95_s),
                fmt_time(r.min_s),
            );
        }
        if let Some(dir) = std::env::var_os("BENCH_JSON_DIR") {
            let path = std::path::Path::new(&dir).join(format!("BENCH_{title}.json"));
            match std::fs::write(&path, self.to_json(title)) {
                Ok(()) => println!("wrote {}", path.display()),
                Err(e) => eprintln!("BENCH_JSON_DIR={dir:?}: write failed: {e}"),
            }
        }
    }

    /// Render the rows as the `BENCH_<name>.json` document consumed by
    /// `scripts/bench_check.py` (wall-time per scenario; schema
    /// documented in DESIGN.md §11).
    pub fn to_json(&self, bench: &str) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(bench)));
        s.push_str(&format!(
            "  \"quick\": {},\n",
            std::env::var_os("BENCH_QUICK").is_some()
        ));
        s.push_str("  \"scenarios\": {\n");
        for (i, r) in self.rows.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {{\"iters\": {}, \"mean_s\": {:e}, \"p50_s\": {:e}, \
                 \"p95_s\": {:e}, \"min_s\": {:e}}}{}\n",
                json_escape(&r.name),
                r.iters,
                r.mean_s,
                r.p50_s,
                r.p95_s,
                r.min_s,
                if i + 1 == self.rows.len() { "" } else { "," }
            ));
        }
        s.push_str("  }\n}\n");
        s
    }

    pub fn rows(&self) -> &[BenchResult] {
        &self.rows
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new()
            .with_budget(Duration::from_millis(20), Duration::from_millis(5));
        let r = b.bench("noop-vec", || vec![0u8; 64]).clone();
        assert!(r.iters >= 10);
        assert!(r.mean_s > 0.0 && r.mean_s < 0.01);
        assert!(r.p50_s <= r.p95_s);
    }

    #[test]
    fn report_does_not_panic() {
        let mut b = Bencher::new()
            .with_budget(Duration::from_millis(5), Duration::from_millis(1));
        b.bench("x", || 1 + 1);
        b.report("t");
    }

    #[test]
    fn json_document_carries_every_scenario() {
        let mut b = Bencher::new()
            .with_budget(Duration::from_millis(5), Duration::from_millis(1));
        b.bench("group/first", || 1 + 1);
        b.bench("group/second", || vec![0u8; 8]);
        let json = b.to_json("unit");
        assert!(json.contains("\"bench\": \"unit\""), "{json}");
        assert!(json.contains("\"group/first\""), "{json}");
        assert!(json.contains("\"group/second\""), "{json}");
        assert!(json.contains("\"mean_s\""), "{json}");
        // exactly one comma between the two scenario lines, none trailing
        assert_eq!(json.matches("}},").count(), 1, "{json}");
        // parses with the in-repo JSON reader (the schema is real JSON)
        let parsed = crate::util::json::Json::parse(&json).expect("valid JSON");
        assert!(parsed.get("scenarios").and_then(|s| s.get("group/first")).is_some());
        assert!(parsed
            .at(&["scenarios", "group/second", "mean_s"])
            .and_then(crate::util::json::Json::as_f64)
            .is_some());
    }
}
