//! Host-CPU baseline (paper Fig 2c): an analytic timing model for the
//! sweep plots plus a *real* int8 executor (GEMM + 3x3 conv with int32
//! accumulation and the shared requantization) so the baseline is an
//! implementation, not just a formula.  The real executor also serves as a
//! native oracle for the quantized layer math.

use crate::config::CpuConfig;
use crate::model::{LayerKind, Model};
use crate::quant::requantize;

/// Analytic CPU inference time for a model (Fig 2c series).
pub fn cpu_time_s(model: &Model, cfg: &CpuConfig) -> f64 {
    let t: f64 = model
        .layers
        .iter()
        .map(|l| {
            let rate = match l.kind() {
                LayerKind::Fc => cfg.rate_fc,
                LayerKind::Conv => cfg.rate_conv,
            };
            l.macs() as f64 / rate
        })
        .sum();
    t + cfg.overhead_s
}

/// Quantized dense layer on the CPU: `y = requant((x - zp_in) @ w + b)`.
///
/// `x`: `(k,)`, `w`: `(k, n)` row-major, `b`: `(n,)`.
pub fn fc_i8(
    x: &[i8],
    w: &[i8],
    b: &[i32],
    k: usize,
    n: usize,
    zp_in: i32,
    mult: f32,
    zp_out: i32,
) -> Vec<i8> {
    assert_eq!(x.len(), k);
    assert_eq!(w.len(), k * n);
    assert_eq!(b.len(), n);
    let mut acc = b.to_vec();
    // ikj loop order: stream rows of w, accumulate into acc (cache friendly)
    for i in 0..k {
        let xi = x[i] as i32 - zp_in;
        if xi == 0 {
            continue;
        }
        let row = &w[i * n..(i + 1) * n];
        for (a, &wv) in acc.iter_mut().zip(row) {
            *a += xi * wv as i32;
        }
    }
    acc.into_iter().map(|a| requantize(a, mult, zp_out)).collect()
}

/// Quantized 3x3 stride-1 SAME conv on the CPU.
///
/// `x`: `(h, w, cin)` HWC, `wt`: `(3, 3, cin, f)`, `b`: `(f,)`.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_i8(
    x: &[i8],
    wt: &[i8],
    b: &[i32],
    h: usize,
    w: usize,
    cin: usize,
    f: usize,
    zp_in: i32,
    mult: f32,
    zp_out: i32,
) -> Vec<i8> {
    assert_eq!(x.len(), h * w * cin);
    assert_eq!(wt.len(), 9 * cin * f);
    assert_eq!(b.len(), f);
    let mut out = vec![0i8; h * w * f];
    let mut acc = vec![0i32; f];
    for oy in 0..h {
        for ox in 0..w {
            acc.copy_from_slice(b);
            for dy in 0..3usize {
                let iy = oy as isize + dy as isize - 1;
                if iy < 0 || iy >= h as isize {
                    continue; // SAME padding contributes (pad - zp_in) = 0
                }
                for dx in 0..3usize {
                    let ix = ox as isize + dx as isize - 1;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let xbase = (iy as usize * w + ix as usize) * cin;
                    let wbase = (dy * 3 + dx) * cin * f;
                    for c in 0..cin {
                        let xv = x[xbase + c] as i32 - zp_in;
                        if xv == 0 {
                            continue;
                        }
                        let wrow = &wt[wbase + c * f..wbase + (c + 1) * f];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv as i32;
                        }
                    }
                }
            }
            let obase = (oy * w + ox) * f;
            for (j, &a) in acc.iter().enumerate() {
                out[obase + j] = requantize(a, mult, zp_out);
            }
        }
    }
    out
}

/// Execute a full quantized FC chain natively (weights supplied per layer).
pub struct NativeFcLayer {
    pub w: Vec<i8>,
    pub b: Vec<i32>,
    pub k: usize,
    pub n: usize,
    pub zp_in: i32,
    pub mult: f32,
    pub zp_out: i32,
}

pub fn run_fc_chain(layers: &[NativeFcLayer], input: &[i8]) -> Vec<i8> {
    let mut x = input.to_vec();
    for l in layers {
        x = fc_i8(&x, &l.w, &l.b, l.k, l.n, l.zp_in, l.mult, l.zp_out);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::{conv_model, fc_model};
    use crate::util::rng::Rng;

    #[test]
    fn analytic_model_shapes() {
        let cfg = CpuConfig::default();
        // slowest FC model ~ 3 ms on the CPU (paper §IV)
        let t = cpu_time_s(&fc_model(2640), &cfg) * 1e3;
        assert!((2.0..5.0).contains(&t), "t={t}");
        // big CONV models are seconds on the CPU
        let t = cpu_time_s(&conv_model(600), &cfg);
        assert!(t > 1.0, "t={t}");
    }

    /// Naive triple-loop oracle for fc_i8.
    fn fc_naive(
        x: &[i8], w: &[i8], b: &[i32], k: usize, n: usize,
        zp_in: i32, mult: f32, zp_out: i32,
    ) -> Vec<i8> {
        (0..n)
            .map(|j| {
                let mut a = b[j];
                for i in 0..k {
                    a += (x[i] as i32 - zp_in) * w[i * n + j] as i32;
                }
                requantize(a, mult, zp_out)
            })
            .collect()
    }

    #[test]
    fn fc_matches_naive() {
        crate::util::proptest::forall(64, |rng| {
            let k = rng.below(50) as usize + 1;
            let n = rng.below(40) as usize + 1;
            let x = rng.i8_vec(k);
            let w = rng.i8_vec(k * n);
            let b: Vec<i32> = (0..n).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
            let zp_in = rng.range_i64(-128, 127) as i32;
            let zp_out = rng.range_i64(-128, 127) as i32;
            let mult = rng.f64_range(1e-5, 0.05) as f32;
            let got = fc_i8(&x, &w, &b, k, n, zp_in, mult, zp_out);
            let want = fc_naive(&x, &w, &b, k, n, zp_in, mult, zp_out);
            crate::check!(got == want, "k={k} n={n}");
            Ok(())
        });
    }

    /// Padding contributes zero because pad value == zp_in.
    #[test]
    fn conv_identity_center_tap() {
        let (h, w, cin, f) = (5, 4, 1, 1);
        let mut rng = Rng::new(2);
        let x = rng.i8_vec(h * w);
        let mut wt = vec![0i8; 9];
        wt[4] = 1; // center tap
        let out = conv3x3_i8(&x, &wt, &[0], h, w, cin, f, 0, 1.0, 0);
        assert_eq!(out, x);
    }

    /// Dense oracle with explicit zero-padded input.
    #[test]
    fn conv_matches_padded_naive() {
        crate::util::proptest::forall(24, |rng| {
            let h = rng.below(6) as usize + 2;
            let w = rng.below(6) as usize + 2;
            let cin = rng.below(4) as usize + 1;
            let f = rng.below(5) as usize + 1;
            let zp_in = rng.range_i64(-100, 100) as i32;
            let zp_out = rng.range_i64(-100, 100) as i32;
            let mult = rng.f64_range(1e-4, 0.02) as f32;
            let x = rng.i8_vec(h * w * cin);
            let wt = rng.i8_vec(9 * cin * f);
            let b: Vec<i32> = (0..f).map(|_| rng.range_i64(-500, 500) as i32).collect();

            let got = conv3x3_i8(&x, &wt, &b, h, w, cin, f, zp_in, mult, zp_out);

            // oracle: pad with zp_in (so xv - zp_in = 0 in the halo)
            let hp = h + 2;
            let wp = w + 2;
            let mut xp = vec![zp_in as i8; hp * wp * cin];
            for y in 0..h {
                for xcol in 0..w {
                    for c in 0..cin {
                        xp[((y + 1) * wp + xcol + 1) * cin + c] = x[(y * w + xcol) * cin + c];
                    }
                }
            }
            let mut want = vec![0i8; h * w * f];
            for oy in 0..h {
                for ox in 0..w {
                    for j in 0..f {
                        let mut a = b[j];
                        for dy in 0..3 {
                            for dx in 0..3 {
                                for c in 0..cin {
                                    let xv =
                                        xp[((oy + dy) * wp + ox + dx) * cin + c] as i32 - zp_in;
                                    let wv = wt[((dy * 3 + dx) * cin + c) * f + j] as i32;
                                    a += xv * wv;
                                }
                            }
                        }
                        want[(oy * w + ox) * f + j] = requantize(a, mult, zp_out);
                    }
                }
            }
            crate::check!(got == want, "h={h} w={w} cin={cin} f={f} zp_in={zp_in}");
            Ok(())
        });
    }

    #[test]
    fn chain_runs() {
        let mut rng = Rng::new(1);
        let l1 = NativeFcLayer {
            w: rng.i8_vec(8 * 6), b: vec![0; 6], k: 8, n: 6,
            zp_in: 0, mult: 0.01, zp_out: -128,
        };
        let l2 = NativeFcLayer {
            w: rng.i8_vec(6 * 3), b: vec![10; 3], k: 6, n: 3,
            zp_in: -128, mult: 0.02, zp_out: 0,
        };
        let x = rng.i8_vec(8);
        let y = run_fc_chain(&[l1, l2], &x);
        assert_eq!(y.len(), 3);
    }
}
