//! Execution traces: CSV/ASCII export of pipeline Gantt schedules for
//! inspecting stage overlap and bottlenecks.

use crate::pipeline::PipelineResult;

/// Gantt schedule as CSV (`stage,item,start_s,end_s`).
pub fn gantt_csv(result: &PipelineResult) -> String {
    let mut out = String::from("stage,item,start_s,end_s\n");
    for e in &result.gantt {
        out.push_str(&format!("{},{},{:.9},{:.9}\n", e.stage, e.item, e.start_s, e.end_s));
    }
    out
}

/// Coarse ASCII Gantt chart (one row per stage, `width` columns over the
/// makespan; digits show which item occupies the slot, '.' = idle).
pub fn gantt_ascii(result: &PipelineResult, width: usize) -> String {
    if result.gantt.is_empty() {
        return String::from("(no gantt recorded)\n");
    }
    let n_stages = result.gantt.iter().map(|e| e.stage).max().unwrap() + 1;
    let span = result.makespan_s.max(1e-12);
    let mut rows = vec![vec!['.'; width]; n_stages];
    for e in &result.gantt {
        let a = ((e.start_s / span) * width as f64) as usize;
        let b = (((e.end_s / span) * width as f64).ceil() as usize).min(width);
        let c = char::from_digit((e.item % 10) as u32, 10).unwrap();
        for cell in rows[e.stage].iter_mut().take(b).skip(a.min(width)) {
            *cell = c;
        }
    }
    let mut out = String::new();
    for (i, row) in rows.iter().enumerate() {
        out.push_str(&format!("TPU{i} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("       0 .. {:.3} ms\n", span * 1e3));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LinkConfig;
    use crate::link::Link;
    use crate::pipeline::{simulate, SimOptions, StageSpec};

    fn run() -> PipelineResult {
        let stages: Vec<StageSpec> = [1e-3, 2e-3]
            .iter()
            .map(|&e| StageSpec { exec_s: e, in_bytes: 10, out_bytes: 10 })
            .collect();
        simulate(
            &stages,
            &Link::new(LinkConfig::default()),
            &SimOptions { batch: 4, queue_capacity: None, record_gantt: true },
        )
    }

    #[test]
    fn csv_has_all_rows() {
        let csv = gantt_csv(&run());
        assert_eq!(csv.lines().count(), 1 + 8); // header + 2 stages x 4 items
        assert!(csv.starts_with("stage,item,"));
    }

    #[test]
    fn ascii_has_stage_rows() {
        let art = gantt_ascii(&run(), 60);
        assert!(art.contains("TPU0 |"));
        assert!(art.contains("TPU1 |"));
        // stage 1 is the bottleneck: its row must be busier than stage 0
        let busy = |row: &str| row.chars().filter(|c| c.is_ascii_digit()).count();
        let lines: Vec<&str> = art.lines().collect();
        assert!(busy(lines[1]) > busy(lines[0]), "{art}");
    }

    #[test]
    fn empty_gantt_handled() {
        let r = simulate(
            &[StageSpec { exec_s: 1e-3, in_bytes: 0, out_bytes: 0 }],
            &Link::new(LinkConfig::default()),
            &SimOptions::default(),
        );
        assert!(gantt_ascii(&r, 10).contains("no gantt"));
    }
}
