//! Execution traces: CSV/ASCII export of pipeline Gantt schedules for
//! inspecting stage overlap and bottlenecks, plus the ASCII renderer for
//! saved span traces (`repro trace`, DESIGN.md §13).

use crate::obs::TraceFile;
use crate::pipeline::PipelineResult;

/// Gantt schedule as CSV (`stage,item,start_s,end_s`).
pub fn gantt_csv(result: &PipelineResult) -> String {
    let mut out = String::from("stage,item,start_s,end_s\n");
    for e in &result.gantt {
        out.push_str(&format!("{},{},{:.9},{:.9}\n", e.stage, e.item, e.start_s, e.end_s));
    }
    out
}

/// One labelled row of spans for [`spans_ascii`]: `(start_s, end_s,
/// glyph)` intervals over a shared time axis.
pub type SpanRow = (String, Vec<(f64, f64, char)>);

/// Render labelled span rows as a coarse ASCII chart (`width` columns
/// over `span_s` seconds; '.' = idle).  A span shorter than one column is
/// clamped to a single cell so it stays visible instead of rounding away.
pub fn spans_ascii(rows: &[SpanRow], span_s: f64, width: usize) -> String {
    let width = width.max(1);
    let span = span_s.max(1e-12);
    let label_w = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (label, spans) in rows {
        let mut cells = vec!['.'; width];
        for &(start_s, end_s, c) in spans {
            let a = (((start_s / span) * width as f64) as usize).min(width - 1);
            let b = ((((end_s / span) * width as f64).ceil() as usize).min(width)).max(a + 1);
            for cell in cells.iter_mut().take(b).skip(a) {
                *cell = c;
            }
        }
        out.push_str(&format!("{label:<label_w$} |{}|\n", cells.iter().collect::<String>()));
    }
    out.push_str(&format!("{:label_w$}  0 .. {:.3} ms\n", "", span * 1e3));
    out
}

/// Coarse ASCII Gantt chart (one row per stage, `width` columns over the
/// makespan; digits show which item occupies the slot, '.' = idle).
pub fn gantt_ascii(result: &PipelineResult, width: usize) -> String {
    if result.gantt.is_empty() {
        return String::from("(no gantt recorded)\n");
    }
    let n_stages = result.gantt.iter().map(|e| e.stage).max().unwrap() + 1;
    let mut rows: Vec<SpanRow> =
        (0..n_stages).map(|i| (format!("TPU{i}"), Vec::new())).collect();
    for e in &result.gantt {
        let c = char::from_digit((e.item % 10) as u32, 10).unwrap();
        rows[e.stage].1.push((e.start_s, e.end_s, c));
    }
    spans_ascii(&rows, result.makespan_s, width)
}

/// Render a saved span trace (see [`crate::obs::export`]) as an ASCII
/// chart: one row per track in track order, glyphs keyed by span id.
pub fn trace_ascii(file: &TraceFile, width: usize) -> String {
    if file.events.is_empty() {
        return String::from("(no spans recorded)\n");
    }
    let mut tracks: Vec<u32> = file.events.iter().map(|e| e.track).collect();
    tracks.sort_unstable();
    tracks.dedup();
    let span_s = file
        .events
        .iter()
        .map(|e| (e.start_us + e.dur_us) as f64 * 1e-6)
        .fold(0.0f64, f64::max);
    let rows: Vec<SpanRow> = tracks
        .iter()
        .map(|&t| {
            let spans = file
                .events
                .iter()
                .filter(|e| e.track == t)
                .map(|e| {
                    let start_s = e.start_us as f64 * 1e-6;
                    let end_s = (e.start_us + e.dur_us) as f64 * 1e-6;
                    let c = char::from_digit((e.id % 10) as u32, 10).unwrap();
                    (start_s, end_s, c)
                })
                .collect();
            (file.track_label(t), spans)
        })
        .collect();
    let mut out = spans_ascii(&rows, span_s, width);
    out.push_str(&format!(
        "{} spans on {} tracks ({} dropped)\n",
        file.events.len(),
        tracks.len(),
        file.dropped
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LinkConfig;
    use crate::link::Link;
    use crate::obs::{SpanEvent, SpanKind};
    use crate::pipeline::{simulate, GanttEntry, SimOptions, StageSpec};

    fn run() -> PipelineResult {
        let stages: Vec<StageSpec> = [1e-3, 2e-3]
            .iter()
            .map(|&e| StageSpec { exec_s: e, in_bytes: 10, out_bytes: 10 })
            .collect();
        simulate(
            &stages,
            &Link::new(LinkConfig::default()),
            &SimOptions { batch: 4, queue_capacity: None, record_gantt: true },
        )
    }

    #[test]
    fn csv_has_all_rows() {
        let csv = gantt_csv(&run());
        assert_eq!(csv.lines().count(), 1 + 8); // header + 2 stages x 4 items
        assert!(csv.starts_with("stage,item,"));
    }

    #[test]
    fn ascii_has_stage_rows() {
        let art = gantt_ascii(&run(), 60);
        assert!(art.contains("TPU0 |"));
        assert!(art.contains("TPU1 |"));
        // stage 1 is the bottleneck: its row must be busier than stage 0
        let busy = |row: &str| row.chars().filter(|c| c.is_ascii_digit()).count();
        let lines: Vec<&str> = art.lines().collect();
        assert!(busy(lines[1]) > busy(lines[0]), "{art}");
    }

    #[test]
    fn empty_gantt_handled() {
        let r = simulate(
            &[StageSpec { exec_s: 1e-3, in_bytes: 0, out_bytes: 0 }],
            &Link::new(LinkConfig::default()),
            &SimOptions::default(),
        );
        assert!(gantt_ascii(&r, 10).contains("no gantt"));
    }

    #[test]
    fn zero_width_spans_stay_visible() {
        // regression: a span shorter than one column used to round to
        // `a == b` and render as idle
        let r = PipelineResult {
            makespan_s: 1.0,
            latencies_s: vec![],
            stage_busy_s: vec![1e-6],
            gantt: vec![GanttEntry { stage: 0, item: 3, start_s: 0.5, end_s: 0.500001 }],
        };
        let art = gantt_ascii(&r, 10);
        assert!(art.contains('3'), "sub-column span must occupy one cell: {art}");
        // and a span at the very end of the axis must not overflow the row
        let r2 = PipelineResult {
            makespan_s: 1.0,
            latencies_s: vec![],
            stage_busy_s: vec![1e-9],
            gantt: vec![GanttEntry { stage: 0, item: 7, start_s: 1.0, end_s: 1.0 }],
        };
        let art2 = gantt_ascii(&r2, 10);
        let bar = art2.lines().next().unwrap();
        assert!(bar.ends_with("7|"), "{art2}");
    }

    #[test]
    fn trace_ascii_renders_tracks() {
        let mut f = TraceFile::new("unit");
        f.name_track(0, "fc/requests");
        f.events = vec![
            SpanEvent { kind: SpanKind::Response, track: 0, id: 1, start_us: 0, dur_us: 900 },
            SpanEvent { kind: SpanKind::Stage, track: 2, id: 1, start_us: 100, dur_us: 500 },
        ];
        let art = trace_ascii(&f, 40);
        assert!(art.contains("fc/requests"), "{art}");
        assert!(art.contains("track2"), "{art}");
        assert!(art.contains("2 spans on 2 tracks"), "{art}");
        assert!(trace_ascii(&TraceFile::new("x"), 40).contains("no spans"));
    }
}
