"""Synthetic model specs mirroring §III-A of the paper and
``rust/src/model/synthetic.rs``.

FC models: ``L_FC`` dense layers; input ``I=64``, hidden width ``n``,
output ``O=10``.  CONV models: ``L_CONV`` conv layers, stride 1, SAME
padding, ``C=3`` input channels, ``W x H = 64 x 64`` images, ``3 x 3``
filters, ``f`` filters per layer.

Weights are generated deterministically from a seed so that the Rust side
(and EXPERIMENTS.md) can refer to models by name alone.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from .quantize import (
    QParams,
    activation_qparams,
    bias_quantize,
    requant_multiplier,
    weight_qparams,
)


@dataclasses.dataclass(frozen=True)
class FcLayer:
    """Dense layer: ``(in_features,) -> (out_features,)``."""

    in_features: int
    out_features: int

    @property
    def macs(self) -> int:
        return self.in_features * self.out_features

    @property
    def weight_bytes(self) -> int:
        return self.in_features * self.out_features  # int8


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """3x3 stride-1 SAME conv: ``(h, w, cin) -> (h, w, filters)``."""

    height: int
    width: int
    cin: int
    filters: int
    ksize: int = 3

    @property
    def macs(self) -> int:
        return self.height * self.width * self.cin * self.filters * self.ksize**2

    @property
    def weight_bytes(self) -> int:
        return self.ksize * self.ksize * self.cin * self.filters


Layer = FcLayer | ConvLayer


def fc_model(n: int, layers: int = 5, inp: int = 64, out: int = 10) -> List[FcLayer]:
    """The paper's FC generator: I -> n -> ... -> n -> O."""
    if layers < 2:
        raise ValueError("need >= 2 layers")
    widths = [inp] + [n] * (layers - 1) + [out]
    return [FcLayer(widths[i], widths[i + 1]) for i in range(layers)]


def conv_model(
    f: int, layers: int = 5, c: int = 3, h: int = 64, w: int = 64
) -> List[ConvLayer]:
    """The paper's CONV generator: C -> f -> ... -> f channels."""
    cins = [c] + [f] * (layers - 1)
    return [ConvLayer(h, w, cins[i], f) for i in range(layers)]


def model_macs(layers: Sequence[Layer]) -> int:
    return sum(l.macs for l in layers)


def input_shape(layers: Sequence[Layer]) -> Tuple[int, ...]:
    first = layers[0]
    if isinstance(first, FcLayer):
        return (first.in_features,)
    return (first.height, first.width, first.cin)


@dataclasses.dataclass(frozen=True)
class QuantLayer:
    """A layer with concrete quantized weights and requant parameters."""

    spec: Layer
    w_q: np.ndarray  # int8; FC: (in, out); CONV: (kh, kw, cin, f)
    b_q: np.ndarray  # int32, (out,)
    in_q: QParams
    out_q: QParams
    mult: float  # requant multiplier in_scale*w_scale/out_scale


def _gen_float_weights(rng: np.random.Generator, spec: Layer):
    if isinstance(spec, FcLayer):
        shape = (spec.in_features, spec.out_features)
        fan_in = spec.in_features
        nout = spec.out_features
    else:
        shape = (spec.ksize, spec.ksize, spec.cin, spec.filters)
        fan_in = spec.ksize * spec.ksize * spec.cin
        nout = spec.filters
    w = rng.normal(0.0, 1.0 / np.sqrt(fan_in), shape).astype(np.float32)
    b = rng.normal(0.0, 0.05, (nout,)).astype(np.float32)
    return w, b


def quantize_model(
    layers: Sequence[Layer], seed: int, act_range: float = 4.0
) -> List[QuantLayer]:
    """Deterministically materialize + quantize a synthetic model.

    Activation ranges use a fixed symmetric-ish calibration window
    ``[-act_range, act_range]`` (plus ReLU clamping at 0 for hidden layers),
    which is what a calibration pass over the synthetic normal inputs
    produces to within noise; fixing it keeps Python/Rust in lockstep.
    """
    rng = np.random.default_rng(seed)
    out: List[QuantLayer] = []
    in_q = activation_qparams(-act_range, act_range)  # model input window
    n = len(layers)
    for i, spec in enumerate(layers):
        w, b = _gen_float_weights(rng, spec)
        wq_params = weight_qparams(w)
        w_q = wq_params.quantize(w)
        b_q = bias_quantize(b, in_q.scale, wq_params.scale)
        last = i == n - 1
        # hidden layers are ReLU-clamped -> [0, act_range); output is linear
        out_q = (
            activation_qparams(-act_range, act_range)
            if last
            else activation_qparams(0.0, act_range)
        )
        mult = requant_multiplier(in_q.scale, wq_params.scale, out_q.scale)
        out.append(QuantLayer(spec, w_q, b_q, in_q, out_q, mult))
        in_q = out_q
    return out
