"""L1 structural perf analysis: VMEM footprint and MXU utilization of the
Pallas kernels across candidate BlockSpecs, at the paper's model scales.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so kernel
optimization here is structural: pick block shapes that (a) fit the Edge
TPU-class VMEM budget with double buffering, (b) keep the 64x64 MXU
systolic array fully populated, (c) minimize HBM re-reads of the weight
tile.  Results are recorded in EXPERIMENTS.md §Perf (L1).

Usage: cd python && python -m compile.kernels.perf_report
"""

from __future__ import annotations

from .conv import conv_vmem_bytes
from .fc import fc_mxu_utilization, fc_vmem_bytes

# Edge TPU-class on-chip budget for kernel working set (weights live in
# the 8 MiB pool too; leave room for 2x double-buffering).
VMEM_BUDGET = 2 * 1024 * 1024
MXU = 64


def fc_table(m: int, k: int, n: int):
    print(f"\nFC layer ({m}x{k})@({k}x{n}) int8 — block-shape candidates")
    print(f"{'bm':>4} {'bk':>5} {'bn':>5} {'vmem_KiB':>9} {'2xbuf_ok':>9} "
          f"{'mxu_util':>9} {'k_steps':>8}")
    best = None
    for bm in (1, 8, 64, 128):
        for bk in (64, 128, 256, 512):
            for bn in (64, 128, 256):
                if bm > m or bk > k or bn > n:
                    continue
                v = fc_vmem_bytes(bm, bk, bn)
                ok = 2 * v <= VMEM_BUDGET
                util = fc_mxu_utilization(bm, bk, bn, MXU)
                steps = -(-k // bk)
                print(f"{bm:>4} {bk:>5} {bn:>5} {v/1024:>9.1f} {str(ok):>9} "
                      f"{util:>9.2f} {steps:>8}")
                # prefer: fits, max util, then fewest K steps (fewest
                # accumulator flushes), then smallest vmem
                key = (ok, util, -steps, -v)
                if best is None or key > best[0]:
                    best = (key, (bm, bk, bn))
    print(f"-> chosen: bm,bk,bn = {best[1]}")
    return best[1]


def conv_table(h: int, w: int, cin: int, f: int, ksize: int = 3):
    print(f"\nCONV layer {h}x{w}x{cin} -> {f} filters ({ksize}x{ksize}) — candidates")
    print(f"{'bc':>4} {'bf':>4} {'vmem_KiB':>9} {'2xbuf_ok':>9} {'mxu_util':>9}")
    best = None
    for bc in (16, 32, 64, 128):
        for bf in (16, 32, 64, 128):
            if bc > cin or bf > f:
                continue
            v = conv_vmem_bytes(h, w, ksize, bc, bf)
            ok = 2 * v <= VMEM_BUDGET
            # contraction dim = ksize^2*bc, output dim = bf
            util = min(1.0, ksize * ksize * bc / MXU) * min(1.0, bf / MXU)
            print(f"{bc:>4} {bf:>4} {v/1024:>9.1f} {str(ok):>9} {util:>9.2f}")
            key = (ok, util, -v)
            if best is None or key > best[0]:
                best = (key, (bc, bf))
    print(f"-> chosen: bc,bf = {best[1]}")
    return best[1]


def main():
    print("=== L1 BlockSpec analysis (Edge TPU-class budget:",
          f"{VMEM_BUDGET // 1024} KiB working set, {MXU}x{MXU} MXU) ===")
    # paper-scale FC hidden layer (n ~ 2048) on a 1-row activation
    fc_table(1, 2048, 2048)
    # paper-scale CONV inner layer (f = 442 pre-spill peak)
    conv_table(64, 64, 442, 442)
    # artifact-scale layers (what aot.py ships)
    fc_table(1, 512, 512)
    conv_table(32, 32, 32, 32)


if __name__ == "__main__":
    main()
