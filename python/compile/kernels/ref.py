"""Pure-jnp oracle for the Pallas kernels.

Computes the same integer arithmetic as ``fc.py`` / ``conv.py`` without
Pallas; kernel outputs must match **exactly** (int8 equality), since both
paths perform identical int32 accumulation and identical f32 requantization.
"""

from __future__ import annotations

import jax.numpy as jnp

QMIN = -128
QMAX = 127


def _requant(acc: jnp.ndarray, b: jnp.ndarray, mult: float, zp_out: int):
    acc = acc + b.astype(jnp.int32)
    scaled = jnp.round(acc.astype(jnp.float32) * jnp.float32(mult))
    q = scaled.astype(jnp.int32) + zp_out
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int8)


def fc_quant_ref(x, w, b, *, zp_in: int, mult: float, zp_out: int):
    """Oracle for :func:`compile.kernels.fc.fc_quant`."""
    acc = jnp.dot(
        x.astype(jnp.int32) - zp_in,
        w.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return _requant(acc, b, mult, zp_out)


def conv_quant_ref(x_padded, w, b, *, zp_in: int, mult: float, zp_out: int):
    """Oracle for :func:`compile.kernels.conv.conv_quant` (pre-padded input)."""
    hp, wp, cin = x_padded.shape
    ksize = w.shape[0]
    h, wdim = hp - ksize + 1, wp - ksize + 1
    xi = x_padded.astype(jnp.int32) - zp_in
    acc = jnp.zeros((h * wdim, w.shape[3]), jnp.int32)
    for dy in range(ksize):
        for dx in range(ksize):
            patch = xi[dy : dy + h, dx : dx + wdim, :].reshape(h * wdim, cin)
            acc = acc + jnp.dot(
                patch, w[dy, dx].astype(jnp.int32), preferred_element_type=jnp.int32
            )
    return _requant(acc, b, mult, zp_out).reshape(h, wdim, -1)
