"""L1 Pallas kernel: quantized 3x3 stride-1 SAME conv (int8 -> int8).

TPU adaptation of the paper's CONV workload: the convolution is expressed
as an **im2col contraction** feeding the MXU — each (dy, dx) filter tap is
a ``(H*W, bc) @ (bc, bf)`` int8 matmul accumulated in an int32 VMEM
scratch.  The grid walks output-filter blocks (``bf``) and input-channel
blocks (``bc``); the BlockSpec pipeline expresses the HBM->VMEM schedule
that the paper's device performs with its weight-stationary systolic flow.

The input arrives pre-padded (SAME, pad value = input zero-point) from the
L2 model so the kernel body stays a pure contraction.

``interpret=True`` — see fc.py for why.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

QMIN = -128
QMAX = 127

BF = 64  # output-filter tile
BC = 64  # input-channel tile


def _conv_kernel(
    x_ref, w_ref, b_ref, o_ref, acc_ref, *, h, w, ksize, nc, zp_in, mult, zp_out
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # one im2col contraction instead of ksize^2 small per-tap dots: the
    # patch matrix is (H*W, ksize^2*bc) and the filter block reshapes to
    # (ksize^2*bc, bf) in matching (dy, dx, c) order.  Identical integer
    # math, but a single large MXU-shaped matmul (and, on the CPU proxy,
    # one well-vectorized dot) — see EXPERIMENTS.md §Perf L1.
    patches = [
        x_ref[dy : dy + h, dx : dx + w, :].reshape(h * w, -1)
        for dy in range(ksize)
        for dx in range(ksize)
    ]
    pat = jnp.concatenate(patches, axis=1).astype(jnp.int32) - zp_in
    tap = w_ref[...].reshape(-1, w_ref.shape[-1]).astype(jnp.int32)
    acc_ref[...] += jnp.dot(pat, tap, preferred_element_type=jnp.int32)

    @pl.when(c == nc - 1)
    def _finish():
        out = acc_ref[...] + b_ref[...].astype(jnp.int32)
        scaled = jnp.round(out.astype(jnp.float32) * jnp.float32(mult))
        q = scaled.astype(jnp.int32) + zp_out
        o_ref[...] = jnp.clip(q, QMIN, QMAX).astype(jnp.int8).reshape(h, w, -1)


def _pick(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


def conv_quant(
    x_padded: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    zp_in: int,
    mult: float,
    zp_out: int,
    bf: int = BF,
    bc: int = BC,
) -> jnp.ndarray:
    """Quantized conv: ``(H+k-1, W+k-1, C) int8 * (k, k, C, F) -> (H, W, F)``.

    ``x_padded`` must already carry SAME padding filled with ``zp_in``.
    """
    hp, wp, cin = x_padded.shape
    ksize, k2, c2, f = w.shape
    assert ksize == k2 and c2 == cin and b.shape == (f,)
    h, wdim = hp - ksize + 1, wp - ksize + 1
    bf, bc = _pick(bf, f), _pick(bc, cin)
    grid = (f // bf, cin // bc)
    kernel = partial(
        _conv_kernel,
        h=h,
        w=wdim,
        ksize=ksize,
        nc=grid[1],
        zp_in=zp_in,
        mult=float(mult),
        zp_out=zp_out,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((hp, wp, bc), lambda j, c: (0, 0, c)),
            pl.BlockSpec((ksize, ksize, bc, bf), lambda j, c: (0, 0, c, j)),
            pl.BlockSpec((bf,), lambda j, c: (j,)),
        ],
        out_specs=pl.BlockSpec((h, wdim, bf), lambda j, c: (0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((h, wdim, f), jnp.int8),
        scratch_shapes=[pltpu.VMEM((h * wdim, bf), jnp.int32)],
        interpret=True,
    )(x_padded, w, b)


def conv_vmem_bytes(h: int, w: int, ksize: int, bc: int, bf: int) -> int:
    """Static VMEM footprint estimate for a block shape (DESIGN.md §Perf)."""
    hp, wp = h + ksize - 1, w + ksize - 1
    return (
        hp * wp * bc  # input tile, int8
        + ksize * ksize * bc * bf  # weight tile, int8
        + bf * 4  # bias
        + h * w * bf * 4  # acc scratch, i32
        + h * w * bf  # out tile, int8
    )
