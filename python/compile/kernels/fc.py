"""L1 Pallas kernel: quantized fully-connected layer (int8 x int8 -> int8).

This is the Edge TPU's bread-and-butter op: the systolic MXU consumes int8
weights/activations and accumulates int32.  The kernel is tiled for the
MXU: a ``(bm, bk) @ (bk, bn)`` contraction per grid step with the int32
accumulator held in VMEM scratch across the K grid dimension
(double-buffered HBM->VMEM streaming is implied by the BlockSpec pipeline).

``interpret=True`` everywhere: real-TPU lowering would emit a Mosaic
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO, which is what ``aot.py`` ships to the Rust runtime.

VMEM footprint per step (int8 unless noted):
``bm*bk + bk*bn + bm*bn*4 (acc, i32) + bn*4 (bias) + bm*bn (out)`` —
see DESIGN.md §Perf for the block-shape sweep.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

QMIN = -128
QMAX = 127

# Default MXU-shaped tiles; shrunk automatically for small operands.
BM, BK, BN = 128, 256, 128


def _fc_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk, zp_in, mult, zp_out):
    """One (i, j, k) grid step of the blocked quantized matmul."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xi = x_ref[...].astype(jnp.int32) - zp_in
    wi = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jnp.dot(xi, wi, preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _finish():
        acc = acc_ref[...] + b_ref[...].astype(jnp.int32)
        scaled = jnp.round(acc.astype(jnp.float32) * jnp.float32(mult))
        q = scaled.astype(jnp.int32) + zp_out
        o_ref[...] = jnp.clip(q, QMIN, QMAX).astype(jnp.int8)


def _pick(block: int, dim: int) -> int:
    """Largest divisor of ``dim`` that is <= block (tile must divide)."""
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


def fc_quant(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    zp_in: int,
    mult: float,
    zp_out: int,
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
) -> jnp.ndarray:
    """Quantized dense layer: ``(M, K) int8 @ (K, N) int8 + b -> (M, N) int8``.

    ``mult`` / ``zp_out`` follow the scheme in ``compile.quantize``; ReLU for
    hidden layers falls out of the output clamp when ``zp_out == -128``.
    """
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2 and b.shape == (n,)
    bm, bk, bn = _pick(bm, m), _pick(bk, kdim), _pick(bn, n)
    grid = (m // bm, n // bn, kdim // bk)
    kernel = partial(
        _fc_kernel, nk=grid[2], zp_in=zp_in, mult=float(mult), zp_out=zp_out
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=True,
    )(x, w, b)


def fc_vmem_bytes(bm: int, bk: int, bn: int) -> int:
    """Static VMEM footprint estimate for a block shape (DESIGN.md §Perf)."""
    return bm * bk + bk * bn + bn * 4 + bm * bn * 4 + bm * bn


def fc_mxu_utilization(bm: int, bk: int, bn: int, mxu: int = 64) -> float:
    """Fraction of MXU lanes busy for a (bm,bk)x(bk,bn) tile on an
    ``mxu x mxu`` systolic array (Edge TPU: 64x64)."""
    eff_m = min(bm, mxu) / mxu if bm < mxu else 1.0
    eff_n = min(bn, mxu) / mxu if bn < mxu else 1.0
    return eff_m * eff_n
