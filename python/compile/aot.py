"""AOT driver: lower every contiguous segment of every manifest model to
HLO **text** artifacts + a metadata manifest for the Rust runtime.

Interchange is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

For an L-layer model every contiguous sub-run ``[i, j)`` is lowered
separately (L*(L+1)/2 artifacts), so the Rust coordinator can realize *any*
contiguous partition — including everything the profiled-exhaustive
segmenter may pick — from prebuilt artifacts.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import List

import numpy as np

from . import model as model_mod
from .specs import (
    QuantLayer,
    conv_model,
    fc_model,
    model_macs,
    quantize_model,
)


def _build_layers(entry: dict):
    if entry["kind"] == "fc":
        return fc_model(
            entry["n"],
            layers=entry.get("layers", 5),
            inp=entry.get("input", 64),
            out=entry.get("output", 10),
        )
    if entry["kind"] == "conv":
        return conv_model(
            entry["f"],
            layers=entry.get("layers", 5),
            c=entry.get("c", 3),
            h=entry.get("h", 64),
            w=entry.get("w", 64),
        )
    raise ValueError(f"unknown model kind {entry['kind']!r}")


def _qparams_json(q) -> dict:
    return {"scale": q.scale, "zero_point": q.zero_point}


def _layer_json(ql: QuantLayer) -> dict:
    spec = ql.spec
    base = {
        "macs": spec.macs,
        "weight_bytes": spec.weight_bytes,
        "in_q": _qparams_json(ql.in_q),
        "out_q": _qparams_json(ql.out_q),
    }
    if hasattr(spec, "in_features"):
        base.update(kind="fc", in_features=spec.in_features, out_features=spec.out_features)
    else:
        base.update(
            kind="conv",
            height=spec.height,
            width=spec.width,
            cin=spec.cin,
            filters=spec.filters,
            ksize=spec.ksize,
        )
    return base


def _golden(qlayers: List[QuantLayer], seed: int) -> dict:
    """Reference input/output vectors (int8) for the whole model, computed
    through the pure-jnp oracle — the Rust integration tests replay these
    against the PJRT-loaded artifacts."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed ^ 0xBEEF)
    first = qlayers[0].spec
    shape = (
        (first.in_features,)
        if hasattr(first, "in_features")
        else (first.height, first.width, first.cin)
    )
    x = rng.integers(-128, 128, shape, dtype=np.int8)
    fwd = model_mod.segment_forward(qlayers, use_pallas=False)
    (y,) = fwd(jnp.asarray(x))
    return {
        "input": np.asarray(x).flatten().tolist(),
        "input_shape": list(shape),
        "output": np.asarray(y).flatten().tolist(),
        "output_shape": list(np.asarray(y).shape),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--manifest",
        default=str(pathlib.Path(__file__).parent / "manifest.json"),
        help="input manifest (models to build)",
    )
    ap.add_argument(
        "--models", nargs="*", default=None, help="subset of model names to build"
    )
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    with open(args.manifest) as f:
        manifest_in = json.load(f)

    out_manifest: dict = {"models": {}}
    for entry in manifest_in["models"]:
        name = entry["name"]
        if args.models and name not in args.models:
            continue
        layers = _build_layers(entry)
        qlayers = quantize_model(layers, entry["seed"])
        nl = len(qlayers)
        segs = []
        for i in range(nl):
            for j in range(i + 1, nl + 1):
                seg = qlayers[i:j]
                fname = f"{name}_seg{i}_{j}.hlo.txt"
                hlo = model_mod.lower_segment(seg, use_pallas=True)
                (out_dir / fname).write_text(hlo)
                segs.append(
                    {
                        "start": i,
                        "end": j,
                        "file": fname,
                        "input_shape": list(model_mod.segment_input_struct(seg).shape),
                        "output_shape": list(model_mod.segment_output_shape(seg)),
                        "in_q": _qparams_json(seg[0].in_q),
                        "out_q": _qparams_json(seg[-1].out_q),
                    }
                )
                print(f"  wrote {fname} ({len(hlo)} chars)")
        out_manifest["models"][name] = {
            "kind": entry["kind"],
            "seed": entry["seed"],
            "macs": model_macs(layers),
            "layers": [_layer_json(ql) for ql in qlayers],
            "segments": segs,
            "golden": _golden(qlayers, entry["seed"]),
        }
        print(f"{name}: {len(segs)} segment artifacts")

    (out_dir / "manifest.json").write_text(json.dumps(out_manifest, indent=1))
    print(f"manifest: {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
