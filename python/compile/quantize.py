"""Uniform affine int8 quantization, mirroring the TFLite scheme the Edge TPU
consumes and the Rust implementation in ``rust/src/quant/``.

Conventions (kept bit-identical between Python/JAX/XLA and Rust):

* ``real = scale * (q - zero_point)``
* weights: per-tensor **symmetric** int8 (``zero_point = 0``)
* activations: per-tensor asymmetric int8 (``zero_point`` in [-128, 127])
* accumulation: int32
* requantization: ``q_out = clip(rint(acc_f32 * mult_f32) + zp_out)`` with
  round-ties-to-even — XLA's ``round_nearest_even`` and Rust's
  ``f32::round_ties_even`` produce identical bits for identical inputs.

The float32 requantization multiplier (instead of TFLite's fixed-point
doubling-high-mul) is a deliberate simplification: it is exactly
reproducible across all three layers of this stack, which is what the
correctness story needs.  Cross-language test vectors live in
``python/tests/test_quantize.py`` and ``rust/src/quant/mod.rs``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

QMIN = -128
QMAX = 127


@dataclasses.dataclass(frozen=True)
class QParams:
    """Per-tensor affine quantization parameters."""

    scale: float
    zero_point: int

    def quantize(self, real: np.ndarray) -> np.ndarray:
        q = np.rint(real / self.scale).astype(np.int64) + self.zero_point
        return np.clip(q, QMIN, QMAX).astype(np.int8)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return (q.astype(np.float32) - self.zero_point) * np.float32(self.scale)


def weight_qparams(w: np.ndarray) -> QParams:
    """Symmetric per-tensor parameters for a weight tensor."""
    amax = float(np.max(np.abs(w)))
    amax = max(amax, 1e-8)
    return QParams(scale=amax / 127.0, zero_point=0)


def activation_qparams(lo: float, hi: float) -> QParams:
    """Asymmetric parameters covering [lo, hi] (must straddle 0)."""
    lo, hi = min(lo, 0.0), max(hi, 0.0)
    scale = max((hi - lo) / (QMAX - QMIN), 1e-8)
    zp = int(np.clip(np.rint(QMIN - lo / scale), QMIN, QMAX))
    return QParams(scale=scale, zero_point=zp)


def bias_quantize(b: np.ndarray, in_scale: float, w_scale: float) -> np.ndarray:
    """Bias is stored int32 at scale ``in_scale * w_scale`` (zp = 0)."""
    return np.rint(b / (in_scale * w_scale)).astype(np.int32)


def requant_multiplier(in_scale: float, w_scale: float, out_scale: float) -> float:
    """The combined rescale factor applied to the int32 accumulator."""
    return float(np.float32(in_scale) * np.float32(w_scale) / np.float32(out_scale))


def requantize_jnp(acc: jnp.ndarray, mult: float, zp_out: int) -> jnp.ndarray:
    """int32 accumulator -> int8 output.  Must match ``quant::requantize``
    in Rust bit-for-bit (f32 multiply, round-ties-even, clamp)."""
    scaled = jnp.round(acc.astype(jnp.float32) * jnp.float32(mult))
    q = scaled.astype(jnp.int32) + zp_out
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int8)


def requantize_np(acc: np.ndarray, mult: float, zp_out: int) -> np.ndarray:
    """NumPy oracle for :func:`requantize_jnp` (np.rint is ties-to-even)."""
    scaled = np.rint(acc.astype(np.float32) * np.float32(mult))
    q = scaled.astype(np.int32) + zp_out
    return np.clip(q, QMIN, QMAX).astype(np.int8)
