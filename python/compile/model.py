"""L2: JAX forward graphs for (segments of) the paper's synthetic models.

A *segment* is a contiguous run of layers of one model — exactly what one
Edge TPU executes in the paper's pipeline.  ``segment_forward`` builds a
jittable int8 -> int8 function whose quantized weights are baked in as HLO
constants (the artifact is self-contained; the Rust runtime feeds only the
int8 activation tensor).  All layer math goes through the L1 Pallas kernels.

Python runs only at build time: ``aot.py`` lowers these functions to HLO
text that ``rust/src/runtime`` loads via PJRT.
"""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv as conv_k
from .kernels import fc as fc_k
from .kernels import ref as ref_k
from .specs import ConvLayer, FcLayer, QuantLayer


def _apply_layer(x: jnp.ndarray, ql: QuantLayer, use_pallas: bool) -> jnp.ndarray:
    w = jnp.asarray(ql.w_q)
    b = jnp.asarray(ql.b_q)
    kw = dict(zp_in=ql.in_q.zero_point, mult=ql.mult, zp_out=ql.out_q.zero_point)
    if isinstance(ql.spec, FcLayer):
        if use_pallas:
            # Perf (EXPERIMENTS.md §Perf L2): size blocks so the artifact
            # models lower to a single grid step — interpret-mode grid
            # loops dominate the lowered HLO's runtime otherwise.  The
            # paper-scale BlockSpec analysis uses the MXU defaults
            # (see kernels/perf_report.py).
            return fc_k.fc_quant(
                x.reshape(1, -1), w, b, bk=512, bn=512, **kw
            ).reshape(-1)
        return ref_k.fc_quant_ref(x.reshape(1, -1), w, b, **kw).reshape(-1)
    assert isinstance(ql.spec, ConvLayer)
    pad = ql.spec.ksize // 2
    xp = jnp.pad(
        x,
        ((pad, pad), (pad, pad), (0, 0)),
        constant_values=np.int8(ql.in_q.zero_point),
    )
    fn = conv_k.conv_quant if use_pallas else ref_k.conv_quant_ref
    return fn(xp, w, b, **kw)


def segment_forward(
    qlayers: Sequence[QuantLayer], use_pallas: bool = True
) -> Callable[[jnp.ndarray], Tuple[jnp.ndarray]]:
    """Build the int8->int8 forward for a contiguous layer run.

    Returns a 1-tuple (lowered with ``return_tuple=True``; the Rust side
    unwraps with ``to_tuple1``).
    """

    def fwd(x: jnp.ndarray) -> Tuple[jnp.ndarray]:
        for ql in qlayers:
            x = _apply_layer(x, ql, use_pallas)
        return (x,)

    return fwd


def segment_input_struct(qlayers: Sequence[QuantLayer]) -> jax.ShapeDtypeStruct:
    first = qlayers[0].spec
    if isinstance(first, FcLayer):
        return jax.ShapeDtypeStruct((first.in_features,), jnp.int8)
    return jax.ShapeDtypeStruct((first.height, first.width, first.cin), jnp.int8)


def segment_output_shape(qlayers: Sequence[QuantLayer]) -> Tuple[int, ...]:
    last = qlayers[-1].spec
    if isinstance(last, FcLayer):
        return (last.out_features,)
    return (last.height, last.width, last.filters)


def split_segments(
    qlayers: Sequence[QuantLayer], cuts: Sequence[int]
) -> List[List[QuantLayer]]:
    """Split by cut positions (indices between layers, ascending)."""
    bounds = [0, *cuts, len(qlayers)]
    assert list(bounds) == sorted(set(bounds)), f"bad cuts {cuts}"
    return [list(qlayers[a:b]) for a, b in zip(bounds, bounds[1:])]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO *text* (xla_extension 0.5.1
    rejects jax>=0.5 serialized protos with 64-bit ids; text round-trips)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked int8 weights must survive the text
    # interchange (default printing elides them as `constant({...})`).
    return comp.as_hlo_text(print_large_constants=True)


def lower_segment(qlayers: Sequence[QuantLayer], use_pallas: bool = True) -> str:
    """Lower one segment to HLO text."""
    fwd = segment_forward(qlayers, use_pallas=use_pallas)
    lowered = jax.jit(fwd).lower(segment_input_struct(qlayers))
    return to_hlo_text(lowered)
