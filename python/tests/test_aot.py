"""AOT driver tests: artifact set completeness, manifest metadata, and that
emitted HLO text carries full (non-elided) weight constants."""

import json
import subprocess
import sys
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = tmp_path_factory.mktemp("cfg") / "manifest.json"
    manifest.write_text(
        json.dumps(
            {
                "models": [
                    {
                        "name": "t_fc",
                        "kind": "fc",
                        "n": 24,
                        "layers": 3,
                        "input": 8,
                        "output": 4,
                        "seed": 3,
                    }
                ]
            }
        )
    )
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--manifest", str(manifest)],
        cwd=ROOT,
        check=True,
        capture_output=True,
    )
    return out


def test_artifact_count(built):
    # L=3 -> L*(L+1)/2 = 6 contiguous segments
    assert len(list(built.glob("t_fc_seg*.hlo.txt"))) == 6


def test_manifest_metadata(built):
    m = json.loads((built / "manifest.json").read_text())
    info = m["models"]["t_fc"]
    assert info["macs"] == 8 * 24 + 24 * 24 + 24 * 4
    assert len(info["layers"]) == 3
    segs = {(s["start"], s["end"]) for s in info["segments"]}
    assert segs == {(i, j) for i in range(3) for j in range(i + 1, 4)}
    whole = next(s for s in info["segments"] if (s["start"], s["end"]) == (0, 3))
    assert whole["input_shape"] == [8] and whole["output_shape"] == [4]
    g = info["golden"]
    assert len(g["input"]) == 8 and len(g["output"]) == 4


def test_no_elided_constants(built):
    for f in built.glob("*.hlo.txt"):
        text = f.read_text()
        assert "{...}" not in text, f"{f.name} has elided constants"
        assert "HloModule" in text


def test_boundary_consistency(built):
    """out_q of segment [i,j) must equal in_q of segment [j,k)."""
    m = json.loads((built / "manifest.json").read_text())
    segs = m["models"]["t_fc"]["segments"]
    by_range = {(s["start"], s["end"]): s for s in segs}
    assert by_range[(0, 1)]["out_q"] == by_range[(1, 2)]["in_q"]
    assert by_range[(1, 2)]["out_q"] == by_range[(2, 3)]["in_q"]
    assert by_range[(0, 2)]["out_q"] == by_range[(2, 3)]["in_q"]
