"""Quantizer properties + the cross-language test vectors shared with
``rust/src/quant/mod.rs`` (keep both sides in sync)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quantize import (
    QMAX,
    QMIN,
    QParams,
    activation_qparams,
    bias_quantize,
    requant_multiplier,
    requantize_np,
    weight_qparams,
)

settings.register_profile("quant", deadline=None, max_examples=200)
settings.load_profile("quant")


@given(st.floats(1e-6, 1e3), st.floats(1e-6, 1e3))
def test_activation_range_covers_zero(lo_mag, hi_mag):
    q = activation_qparams(-lo_mag, hi_mag)
    # zero must be exactly representable (required for zero padding)
    assert QMIN <= q.zero_point <= QMAX
    assert abs(q.dequantize(np.array([q.zero_point], np.int8))[0]) == 0.0


@given(
    st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=64),
)
def test_weight_quant_roundtrip_error_bounded(vals):
    w = np.asarray(vals, np.float32)
    q = weight_qparams(w)
    err = np.abs(q.dequantize(q.quantize(w)) - w)
    assert np.all(err <= q.scale * 0.5 + 1e-6)


@given(st.integers(-(2**20), 2**20), st.floats(1e-6, 0.5), st.integers(QMIN, QMAX))
def test_requantize_in_range(acc, mult, zp):
    out = requantize_np(np.array([acc]), mult, zp)
    assert QMIN <= out[0] <= QMAX


def test_requantize_ties_to_even():
    # acc * mult == 0.5 and 1.5 exactly: ties-to-even -> 0 and 2
    out = requantize_np(np.array([1, 3], np.int32), 0.5, 0)
    np.testing.assert_array_equal(out, np.array([0, 2], np.int8))


def test_cross_language_vectors():
    """Golden vectors mirrored in rust/src/quant/mod.rs::cross_language_vectors.
    If these change, change the Rust test too."""
    got = requantize_np(
        np.array([0, 1000, -1000, 123456, -123456, 2**30], np.int32),
        0.00390625,  # 1/256, exact in f32
        3,
    )
    np.testing.assert_array_equal(
        got, np.array([3, 7, -1, 127, -128, 127], np.int8)
    )
    q = QParams(scale=0.05, zero_point=-10)
    np.testing.assert_array_equal(
        q.quantize(np.array([-1.0, 0.0, 0.024, 0.026, 7.0], np.float32)),
        np.array([-30, -10, -10, -9, 127], np.int8),
    )
    np.testing.assert_array_equal(
        bias_quantize(np.array([0.5, -0.25], np.float32), 0.1, 0.02),
        np.array([250, -125], np.int32),
    )
    assert abs(requant_multiplier(0.1, 0.02, 0.05) - 0.04) < 1e-7  # f32 math
