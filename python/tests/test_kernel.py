"""Kernel-vs-oracle correctness: the CORE signal for L1.

The Pallas kernels and the pure-jnp oracle perform identical integer math,
so outputs must match **exactly** (int8 equality), across a hypothesis
sweep of shapes, block sizes and quantization parameters.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as conv_k
from compile.kernels import fc as fc_k
from compile.kernels import ref as ref_k

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


def _rand(rng, shape, dtype=np.int8):
    return jnp.asarray(rng.integers(-128, 128, shape, dtype=dtype))


# ---------------------------------------------------------------- FC


@given(
    m=st.integers(1, 9),
    k=st.integers(1, 300),
    n=st.integers(1, 200),
    zp_in=st.integers(-128, 127),
    zp_out=st.integers(-128, 127),
    mult=st.floats(1e-6, 0.1, allow_nan=False, allow_infinity=False),
    seed=st.integers(0, 2**31),
)
def test_fc_matches_ref(m, k, n, zp_in, zp_out, mult, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (m, k)), _rand(rng, (k, n))
    b = jnp.asarray(rng.integers(-(2**15), 2**15, (n,), dtype=np.int32))
    kw = dict(zp_in=zp_in, mult=mult, zp_out=zp_out)
    got = fc_k.fc_quant(x, w, b, **kw)
    want = ref_k.fc_quant_ref(x, w, b, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bk,bn", [(1, 1, 1), (2, 8, 4), (128, 256, 128), (3, 7, 5)])
def test_fc_block_shapes(bm, bk, bn):
    rng = np.random.default_rng(0)
    m, k, n = 6, 56, 40
    x, w = _rand(rng, (m, k)), _rand(rng, (k, n))
    b = jnp.asarray(rng.integers(-1000, 1000, (n,), dtype=np.int32))
    kw = dict(zp_in=7, mult=0.004, zp_out=-3)
    got = fc_k.fc_quant(x, w, b, bm=bm, bk=bk, bn=bn, **kw)
    want = ref_k.fc_quant_ref(x, w, b, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fc_saturates():
    """Large accumulators must clamp to the int8 range, not wrap."""
    x = jnp.full((1, 64), 127, jnp.int8)
    w = jnp.full((64, 8), 127, jnp.int8)
    b = jnp.zeros((8,), jnp.int32)
    hi = fc_k.fc_quant(x, w, b, zp_in=0, mult=1.0, zp_out=0)
    lo = fc_k.fc_quant(x, -w, b, zp_in=0, mult=1.0, zp_out=0)
    assert np.all(np.asarray(hi) == 127) and np.all(np.asarray(lo) == -128)


def test_fc_relu_via_zero_point():
    """zp_out = -128 implements quantized ReLU through the clamp."""
    rng = np.random.default_rng(3)
    x, w = _rand(rng, (4, 32)), _rand(rng, (32, 16))
    b = jnp.zeros((16,), jnp.int32)
    out = fc_k.fc_quant(x, w, b, zp_in=0, mult=1e-4, zp_out=-128)
    assert np.all(np.asarray(out) >= -128)  # trivially true; exactness below
    want = ref_k.fc_quant_ref(x, w, b, zp_in=0, mult=1e-4, zp_out=-128)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------------------------- CONV


@given(
    h=st.integers(2, 12),
    w=st.integers(2, 12),
    cin=st.integers(1, 20),
    f=st.integers(1, 24),
    zp_in=st.integers(-128, 127),
    zp_out=st.integers(-128, 127),
    mult=st.floats(1e-6, 0.05, allow_nan=False, allow_infinity=False),
    seed=st.integers(0, 2**31),
)
def test_conv_matches_ref(h, w, cin, f, zp_in, zp_out, mult, seed):
    rng = np.random.default_rng(seed)
    xp = _rand(rng, (h + 2, w + 2, cin))
    wt = _rand(rng, (3, 3, cin, f))
    b = jnp.asarray(rng.integers(-(2**15), 2**15, (f,), dtype=np.int32))
    kw = dict(zp_in=zp_in, mult=mult, zp_out=zp_out)
    got = conv_k.conv_quant(xp, wt, b, **kw)
    want = ref_k.conv_quant_ref(xp, wt, b, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bf,bc", [(1, 1), (4, 2), (64, 64), (3, 5)])
def test_conv_block_shapes(bf, bc):
    rng = np.random.default_rng(1)
    h, w, cin, f = 8, 8, 10, 12
    xp = _rand(rng, (h + 2, w + 2, cin))
    wt = _rand(rng, (3, 3, cin, f))
    b = jnp.asarray(rng.integers(-500, 500, (f,), dtype=np.int32))
    kw = dict(zp_in=-5, mult=0.002, zp_out=11)
    got = conv_k.conv_quant(xp, wt, b, bf=bf, bc=bc, **kw)
    want = ref_k.conv_quant_ref(xp, wt, b, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_identity_filter():
    """A delta filter with unit multiplier reproduces the (shifted) input."""
    h, w, c = 6, 6, 1
    x = np.arange(h * w, dtype=np.int8).reshape(h, w, 1) % 100
    xp = jnp.asarray(np.pad(x, ((1, 1), (1, 1), (0, 0))))
    wt = np.zeros((3, 3, 1, 1), np.int8)
    wt[1, 1, 0, 0] = 1  # center tap
    out = conv_k.conv_quant(
        jnp.asarray(xp), jnp.asarray(wt), jnp.zeros((1,), jnp.int32),
        zp_in=0, mult=1.0, zp_out=0,
    )
    np.testing.assert_array_equal(np.asarray(out), x)


# ------------------------------------------------- VMEM/MXU estimators


def test_fc_vmem_estimate_monotone():
    assert fc_k.fc_vmem_bytes(128, 256, 128) > fc_k.fc_vmem_bytes(64, 128, 64)


def test_mxu_utilization_bounds():
    assert fc_k.fc_mxu_utilization(128, 256, 128) == 1.0
    assert 0 < fc_k.fc_mxu_utilization(1, 256, 1) < 0.01
