"""L2 model tests: synthetic generators match the paper's closed forms;
segment chaining is exactly equivalent to whole-model execution (the
property the multi-TPU pipeline relies on)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile.specs import (
    conv_model,
    fc_model,
    model_macs,
    quantize_model,
)


def test_fc_macs_formula():
    # paper: 64n + 3n^2 + 10n for L=5, I=64, O=10
    for n in (100, 1140, 2640):
        assert model_macs(fc_model(n)) == 64 * n + 3 * n * n + 10 * n


def test_conv_macs_formula():
    # paper: #MACs(f) = W*H*f*Fw*Fh*(C + f*(L-1))
    for f in (32, 292, 702):
        want = 64 * 64 * f * 3 * 3 * (3 + f * 4)
        assert model_macs(conv_model(f)) == want


def test_weight_bytes():
    layers = fc_model(100)
    assert [l.weight_bytes for l in layers] == [6400, 10000, 10000, 10000, 1000]
    cl = conv_model(8, h=16, w=16)
    assert [l.weight_bytes for l in cl] == [3 * 3 * 3 * 8] + [3 * 3 * 8 * 8] * 4


def test_quantize_deterministic():
    a = quantize_model(fc_model(32), seed=5)
    b = quantize_model(fc_model(32), seed=5)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(la.w_q, lb.w_q)
        np.testing.assert_array_equal(la.b_q, lb.b_q)
    c = quantize_model(fc_model(32), seed=6)
    assert any(not np.array_equal(la.w_q, lc.w_q) for la, lc in zip(a, c))


def test_boundary_qparams_chain():
    qls = quantize_model(fc_model(16), seed=1)
    for prev, nxt in zip(qls, qls[1:]):
        assert prev.out_q == nxt.in_q


@pytest.mark.parametrize(
    "layers,seed",
    [(fc_model(48, layers=5, inp=16, out=6), 11), (conv_model(6, c=3, h=10, w=10), 12)],
)
@pytest.mark.parametrize("cuts", [[], [1], [2, 4], [1, 2, 3], [1, 2, 3, 4]])
def test_segment_chain_equals_whole(layers, seed, cuts):
    """Chaining segment outputs int8->int8 must reproduce the un-segmented
    model exactly: this is why pipelining preserves numerics in the paper."""
    qls = quantize_model(layers, seed=seed)
    rng = np.random.default_rng(seed)
    shape = (
        (layers[0].in_features,)
        if hasattr(layers[0], "in_features")
        else (layers[0].height, layers[0].width, layers[0].cin)
    )
    x = jnp.asarray(rng.integers(-128, 128, shape, dtype=np.int8))

    (whole,) = model_mod.segment_forward(qls, use_pallas=True)(x)
    y = x
    for seg in model_mod.split_segments(qls, cuts):
        (y,) = model_mod.segment_forward(seg, use_pallas=True)(y)
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(y))


def test_pallas_vs_ref_whole_model():
    qls = quantize_model(fc_model(40, layers=4, inp=12, out=5), seed=9)
    x = jnp.asarray(np.random.default_rng(0).integers(-128, 128, (12,), np.int8))
    (a,) = model_mod.segment_forward(qls, use_pallas=True)(x)
    (b,) = model_mod.segment_forward(qls, use_pallas=False)(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantized_model_tracks_float_model():
    """End-to-end sanity: the int8 path approximates the float32 path."""
    layers = fc_model(64, layers=3, inp=16, out=8)
    qls = quantize_model(layers, seed=21)
    rng = np.random.default_rng(21)
    xf = rng.normal(0, 1, (16,)).astype(np.float32)
    xq = qls[0].in_q.quantize(xf)

    (yq,) = model_mod.segment_forward(qls, use_pallas=False)(jnp.asarray(xq))
    y_deq = qls[-1].out_q.dequantize(np.asarray(yq))

    # float reference with the SAME (quantized-then-dequantized) weights
    h = qls[0].in_q.dequantize(xq)
    for i, ql in enumerate(qls):
        w_deq = ql.w_q.astype(np.float32) * np.float32(
            ql.mult * ql.out_q.scale / ql.in_q.scale
        )
        b_deq = ql.b_q.astype(np.float32) * np.float32(ql.in_q.scale) * np.float32(
            ql.mult * ql.out_q.scale / ql.in_q.scale
        )
        h = h @ w_deq + b_deq
        if i < len(qls) - 1:
            h = np.maximum(h, 0.0)
    # quantization noise grows with depth; demand agreement within a few LSB
    tol = 4 * qls[-1].out_q.scale
    assert np.max(np.abs(y_deq - h)) <= tol


def test_hlo_text_lowering_smoke():
    qls = quantize_model(fc_model(16, layers=2, inp=8, out=4), seed=2)
    hlo = model_mod.lower_segment(qls, use_pallas=True)
    assert "HloModule" in hlo and "ENTRY" in hlo
    # baked weights appear as constants; entry takes only the activation
    assert "s8[8]" in hlo.replace(" ", "")[:20000] or "s8[8]{0}" in hlo
